"""End-to-end driver: SWAP-train a ~100M-parameter transformer LM for a few
hundred steps on the synthetic Markov-chain corpus.

Default is the ~100M model (12L x d768, vocab 2048); pass --smoke for a
30-second variant. Any assigned architecture works via --arch.

  PYTHONPATH=src python examples/train_lm_swap.py [--smoke] \
      [--arch internlm2-1.8b] [--workers 4] \
      [--checkpoint-dir ckpts/ --checkpoint-every 20] [--resume] \
      [--mesh worker:4,data:2] [--elastic-deadline 30]

With --checkpoint-dir set, the run snapshots its TrainState every
--checkpoint-every steps (epoch-aligned); kill it at any point and relaunch
with --resume to continue bit-exactly from the newest snapshot.

The --mesh/--workers/--elastic-* flag group is the unified
``repro.dist.DistConfig`` surface (same flags as repro.launch.train; see
docs/sharding.md).
"""
import argparse

import jax

from repro.configs import registry
from repro.configs.base import (ModelConfig, OptimizerConfig, PhaseConfig,
                                ScheduleConfig, SWAPConfig)
from repro.core import SWAP, LMAdapter
from repro.data.pipeline import Loader, make_markov_lm
from repro.dist.config import DistConfig, add_dist_args


def repro_100m() -> ModelConfig:
    """~100M-param dense LM sized for a few hundred CPU steps."""
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=2048,
        attention="gqa", rope_theta=10000.0, norm="rmsnorm", act="silu",
        dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="")
    add_dist_args(ap)
    ap.add_argument("--steps1", type=int, default=200)
    ap.add_argument("--steps2", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--phase1-precision", default="float32",
                    choices=["float32", "bfloat16", "float16"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    dist = DistConfig.from_args(args, n_workers_default=4)
    dist.initialize()
    if args.dump_dist_config:
        dist.to_json(args.dump_dist_config)

    if args.arch:
        cfg = registry.get_smoke_config(args.arch)
    elif args.smoke:
        cfg = registry.get_smoke_config("internlm2-1.8b")
    else:
        cfg = repro_100m()
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    data = make_markov_lm(0, vocab=min(cfg.vocab_size, 2048), n_train=4096,
                          n_test=1024, seq_len=args.seq_len)
    train = {"tokens": data["train_tokens"] % cfg.vocab_size,
             "labels": data["train_labels"] % cfg.vocab_size}
    test_loader = Loader({"tokens": data["test_tokens"] % cfg.vocab_size,
                          "labels": data["test_labels"] % cfg.vocab_size},
                         256)

    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    steps1 = 40 if args.smoke else args.steps1
    steps2 = 15 if args.smoke else args.steps2
    swap_cfg = SWAPConfig(
        n_workers=dist.n_workers,
        phase1=PhaseConfig(batch_size=64, max_steps=steps1, stop_accuracy=0.7,
                           precision=args.phase1_precision,
                           grad_accum_steps=args.grad_accum,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.5,
                                                   warmup_steps=steps1 // 5,
                                                   total_steps=steps1)),
        phase2=PhaseConfig(batch_size=16, max_steps=steps2,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.1,
                                                   warmup_steps=0,
                                                   total_steps=steps2)),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    res = SWAP(adapter, swap_cfg, train, test_loader, dist=dist).run(
        jax.random.PRNGKey(0), resume=args.resume)
    print(f"phase1: {res['phase1_steps']} steps, "
          f"test acc {res['phase1_test_acc']:.4f}")
    print(f"workers: {['%.4f' % a for a in res['worker_test_accs']]}")
    print(f"SWAP averaged: {res['after_avg_test_acc']:.4f} "
          f"(before: {res['before_avg_test_acc']:.4f})")
    print(f"times: p1 {res['phase1_time']:.1f}s p2 {res['phase2_time']:.1f}s "
          f"(+{res['phase2_eval_time']:.1f}s eval) "
          f"p3 {res['phase3_time']:.1f}s")


if __name__ == "__main__":
    main()
