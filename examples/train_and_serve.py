"""Train, publish, and serve in ONE process — SWAP's production story.

The paper's pitch is a model that trains fast AND serves well; this driver
closes the loop live. SWAP phase 2 runs W independent small-batch workers,
and at every epoch boundary a ``WeightPublisher`` hook folds the
across-worker mean into a running average (online SWA over the SWAP
ensemble) and hot-swaps the new weight *generation* into a
``CompiledServingEngine`` that is answering requests BETWEEN training
chunks. In-flight requests finish token-exactly on the weights they were
admitted under (per-slot generation pinning); new admissions pick up the
latest average.

  PYTHONPATH=src python examples/train_and_serve.py \
      [--workers 2] [--steps2 48] [--publish-dir ckpts_pub/]

At exit each served request is re-checked against an isolated reference
generation under its pinned weight snapshot (reloaded from the publish
directory) — the train→publish→serve path is verified token-exact.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.state import list_publishes, load_publish
from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig, ScheduleConfig,
                                SWAPConfig)
from repro.core import SWAP, LMAdapter
from repro.data.pipeline import Loader, make_markov_lm
from repro.launch.serve import generate
from repro.serve import CompiledServingEngine, Request, WeightPublisher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--steps1", type=int, default=24)
    ap.add_argument("--steps2", type=int, default=48)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--publish-dir", default="",
                    help="publish snapshot dir (default: a temp dir)")
    ap.add_argument("--requests-per-epoch", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=10)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    pub_dir = args.publish_dir or tempfile.mkdtemp(prefix="swap_publish_")

    # small corpus so phase 2 crosses several epoch boundaries (each one
    # is a publish): 512 samples / batch 32 = 16 steps per epoch
    data = make_markov_lm(0, vocab=min(cfg.vocab_size, 2048), n_train=512,
                          n_test=256, seq_len=args.seq_len)
    train = {"tokens": data["train_tokens"] % cfg.vocab_size,
             "labels": data["train_labels"] % cfg.vocab_size}
    test_loader = Loader({"tokens": data["test_tokens"] % cfg.vocab_size,
                          "labels": data["test_labels"] % cfg.vocab_size},
                         128)

    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    swap_cfg = SWAPConfig(
        n_workers=args.workers,
        phase1=PhaseConfig(batch_size=64, max_steps=args.steps1,
                           stop_accuracy=0.7,
                           schedule=ScheduleConfig(
                               kind="warmup_linear", peak_lr=0.5,
                               warmup_steps=max(1, args.steps1 // 5),
                               total_steps=args.steps1)),
        phase2=PhaseConfig(batch_size=32, max_steps=args.steps2,
                           schedule=ScheduleConfig(
                               kind="warmup_linear", peak_lr=0.1,
                               warmup_steps=0, total_steps=args.steps2)))

    # the engine exists BEFORE training finishes: it starts on the random
    # init (generation 0) and is upgraded live as phase 2 publishes
    model = adapter.model
    init_params = model.init(jax.random.PRNGKey(7))
    prompt_len = 8
    engine = CompiledServingEngine(
        model, init_params, max_batch=2,
        max_seq=prompt_len + args.new_tokens + 8, decode_block=4,
        prefill_buckets=[prompt_len])
    engine.warmup(dual=True)
    publisher = WeightPublisher([engine], directory=pub_dir)

    served = []
    pkey = jax.random.PRNGKey(123)

    def pump(state, done):
        """Admit fresh requests and advance the engine a little between
        training chunks — deliberately NOT draining, so the next publish
        lands while requests are in flight (exercising the dual-generation
        decode path)."""
        for _ in range(args.requests_per_epoch):
            prompt = jax.random.randint(
                jax.random.fold_in(pkey, len(served)), (prompt_len,), 0,
                cfg.vocab_size, dtype=jnp.int32)
            # staggered budgets: alternate requests run longer, so slots
            # pinned to the previous generation overlap with fresh ones
            budget = args.new_tokens + (len(served) % 2) * 7
            req = Request(rid=len(served), prompt=prompt,
                          max_new_tokens=budget)
            served.append(req)
            engine.submit(req)
        for _ in range(2):
            engine.step()

    # publisher FIRST, pump second: every admission happens at a
    # just-published generation, never the random init
    res = SWAP(adapter, swap_cfg, train, test_loader).run(
        jax.random.PRNGKey(0), phase2_hooks=[publisher.on_epoch, pump])
    while engine.active or engine.waiting:
        engine.step()

    print(f"\nphase1: {res['phase1_steps']} steps, "
          f"test acc {res['phase1_test_acc']:.4f}")
    print(f"SWAP averaged: {res['after_avg_test_acc']:.4f} "
          f"(before: {res['before_avg_test_acc']:.4f})")
    print(f"published {publisher.generation} generations to {pub_dir}")

    st = engine.stats
    assert st["decode_transfers"] == st["decode_calls"], \
        "publishing added host syncs to the decode hot loop"
    print(f"engine: {st['decode_calls']} decode calls, "
          f"{st['decode_transfers']} transfers, "
          f"{st['publish_swaps']} swaps, "
          f"{st['dual_decode_calls']} dual-generation calls")

    # token-exactness audit: each request must match an isolated reference
    # generation under its pinned snapshot, reloaded from the publish dir
    by_gen = {p["generation"]: p["path"] for p in list_publishes(pub_dir)}
    checked = 0
    for req in served:
        if not req.done or req.generation not in by_gen:
            continue
        params_g = load_publish(by_gen[req.generation], init_params)
        out, _ = generate(model, params_g, req.prompt[None, :],
                          len(req.generated))
        ref = [int(t) for t in out[0]]
        assert req.generated == ref, (
            f"request {req.rid} (generation {req.generation}) diverged "
            f"from its pinned snapshot: {req.generated} vs {ref}")
        checked += 1
    gens = sorted({r.generation for r in served if r.done})
    print(f"token-exactness audit: {checked} requests across "
          f"generations {gens} all match their pinned snapshots")


if __name__ == "__main__":
    main()
