"""Loss-landscape visualization (paper Figures 2/3): writes the train/test
error grid over the (LB, SGD, SWAP) plane to results/figure23.json and
renders an ASCII heat map.

  PYTHONPATH=src python examples/landscape_viz.py
"""
import json
import os

from benchmarks.figure23_landscape import run


def ascii_map(grid, key, n=9):
    vals = sorted(g[key] for g in grid)
    lo, hi = vals[0], vals[-1]
    chars = " .:-=+*#%@"
    rows = {}
    for g in grid:
        rows.setdefault(round(g["beta"], 6), []).append(g)
    print(f"\n{key} (low '{chars[0]}' ... high '{chars[-1]}'), "
          f"range [{lo:.3f}, {hi:.3f}]")
    for beta in sorted(rows, reverse=True):
        line = ""
        for g in sorted(rows[beta], key=lambda g: g["alpha"]):
            t = (g[key] - lo) / (hi - lo + 1e-12)
            line += chars[min(int(t * (len(chars) - 1)), len(chars) - 1)] * 2
        print(line)


def main():
    os.makedirs("results", exist_ok=True)
    res = run(verbose=True)
    with open("results/figure23.json", "w") as f:
        json.dump(res, f, indent=1)
    ascii_map(res["grid"], "train_err")
    ascii_map(res["grid"], "test_err")
    print("\npoints:", res["points"])


if __name__ == "__main__":
    main()
