"""Batched serving example: prefill a batch of prompts on any assigned
architecture and decode tokens with the KV/state cache (full-attention,
sliding-window, MLA-latent, and SSM caches all exercised).

  PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-2.7b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.serve import generate
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b",
                    choices=registry.ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), model.dtype)
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), model.dtype)

    out, stats = generate(model, params, prompts, args.new_tokens,
                          extras=extras)
    print(f"{args.arch} ({cfg.family}): batch={B} "
          f"prompt={args.prompt_len} +{args.new_tokens} tokens")
    print(f"prefill {stats['prefill_s']*1e3:.0f}ms  "
          f"decode {stats['decode_s']*1e3:.0f}ms  "
          f"{stats['tokens_per_s']:.0f} tok/s")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
