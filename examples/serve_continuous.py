"""Continuous-batching serving: requests of different lengths stream
through a fixed slot pool sharing one decode program and one cache.

  PYTHONPATH=src python examples/serve_continuous.py [--arch mamba2-2.7b] \
      [--engine {loop,compiled}]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models.model import Model
from repro.serve import CompiledServingEngine, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.ASSIGNED_ARCHS)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--engine", default="compiled",
                    choices=["loop", "compiled"],
                    help="compiled = fused K-token decode under one jit; "
                         "loop = the per-step oracle engine")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    if args.engine == "compiled":
        engine = CompiledServingEngine(model, params, max_batch=args.slots,
                                       max_seq=96, decode_block=4)
    else:
        engine = ServingEngine(model, params, max_batch=args.slots,
                               max_seq=96)

    reqs = []
    for i in range(args.requests):
        L = 6 + 3 * i
        prompt = jax.random.randint(jax.random.fold_in(key, i), (L,), 0,
                                    cfg.vocab_size, dtype=jnp.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=5 + i))

    t0 = time.perf_counter()
    results = engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"{args.arch} [{args.engine}]: {args.requests} requests through "
          f"{args.slots} slots -> {total} tokens in {dt:.1f}s")
    if args.engine == "compiled":
        st = engine.stats
        print(f"  {st['decode_calls']} fused decode calls, "
              f"{st['decode_transfers']} bulk host transfers, "
              f"{st['admissions']} admissions")
    for rid, toks in results.items():
        print(f"  req {rid} ({len(reqs[rid].prompt)}-token prompt): {toks}")


if __name__ == "__main__":
    main()
