"""Quickstart: the complete SWAP pipeline in ~60 seconds on CPU.

Trains the paper-faithful CNN+BatchNorm on the synthetic image task with
all three phases, prints per-phase results, and shows the averaged model
beating its workers.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig, ScheduleConfig,
                                SWAPConfig)
from repro.core import SWAP, CNNAdapter
from repro.data.pipeline import Loader, make_gmm_images


def main():
    # 1. data: finite synthetic train set + held-out test set
    data = make_gmm_images(seed=0, n_classes=10, image_size=16,
                           n_train=2048, n_test=1024, noise=3.5)
    train = {"images": data["train_images"], "labels": data["train_labels"]}
    test_loader = Loader({"images": data["test_images"],
                          "labels": data["test_labels"]}, 256)

    # 2. model + optimizer (paper: SGD, momentum .9, wd 5e-4)
    adapter = CNNAdapter(registry.get_smoke_config("cifar-cnn"),
                         OptimizerConfig(kind="sgd"))

    # 3. SWAP: large-batch phase until 95% train accuracy, then 4 workers
    cfg = SWAPConfig(
        n_workers=4,
        phase1=PhaseConfig(batch_size=512, max_steps=120, stop_accuracy=0.95,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=1.2,
                                                   warmup_steps=24,
                                                   total_steps=120)),
        phase2=PhaseConfig(batch_size=64, max_steps=48,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.1, warmup_steps=0,
                                                   total_steps=48)))
    res = SWAP(adapter, cfg, train, test_loader).run(jax.random.PRNGKey(0))

    print(f"phase 1: {res['phase1_steps']} large-batch steps "
          f"-> test {res['phase1_test_acc']:.3f} "
          f"({res['phase1_time']:.1f}s)")
    print(f"phase 2: {cfg.n_workers} independent workers "
          f"({res['phase2_time']:.1f}s)")
    for w, acc in enumerate(res["worker_test_accs"]):
        print(f"  worker {w}: test {acc:.3f}")
    print(f"phase 3: averaged model -> test {res['after_avg_test_acc']:.3f} "
          f"({res['phase3_time']:.1f}s, BN stats recomputed)")
    gain = res["after_avg_test_acc"] - res["before_avg_test_acc"]
    print(f"averaging gain over mean worker: {gain:+.3f}")


if __name__ == "__main__":
    main()
