"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "whisper-base": "repro.configs.whisper_base",
    "cifar-cnn": "repro.configs.cifar_cnn",
    "deepseek-v2-lite": "repro.configs.deepseek_v2_lite",
}

# The 10 assigned architectures (cifar-cnn is the paper-faithful extra;
# deepseek-v2-lite is a beyond-assignment MLA+MoE composition bonus).
_EXTRAS = ("cifar-cnn", "deepseek-v2-lite")
ASSIGNED_ARCHS: List[str] = [a for a in _MODULES if a not in _EXTRAS]
BONUS_ARCHS: List[str] = ["deepseek-v2-lite"]


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ASSIGNED_ARCHS}
