"""qwen3-moe-235b-a22b [moe] — 94L, 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B family]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151936,
        attention="gqa", qkv_bias=False, rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536, capacity_factor=1.25),
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=512,
        attention="gqa",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=1.5),
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
