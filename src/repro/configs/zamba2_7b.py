"""zamba2-7b [hybrid] — Mamba-2 trunk + ONE shared attention block applied
every 6 mamba layers (weights shared across applications). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14336, vocab_size=32000,
        attention="gqa", qkv_bias=False, rope_theta=10_000.0,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        shared_attn_every=6,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        attention="gqa",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk_size=32),
        shared_attn_every=2,
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
