"""BONUS (beyond assignment): deepseek-v2-lite [moe+mla] — demonstrates the
framework composing MLA attention with MoE FFNs in one architecture
(27L d_model=2048, MLA kv_lora=512, 64 experts top-6 + 2 shared experts).
[arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab_size=102400,
        attention="mla", rope_theta=10_000.0,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408,
                      capacity_factor=1.25),
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=64, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=1.5),
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
