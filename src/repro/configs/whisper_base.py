"""whisper-base [audio] — encoder-decoder; mel/conv frontend STUBBED to frame
embeddings (1500, d_model) supplied by input_specs. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=51865,
        attention="gqa", qkv_bias=True, rope_theta=10_000.0,
        is_encoder_decoder=True, n_encoder_layers=6, encoder_seq=1500,
        norm="layernorm", act="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        attention="gqa", qkv_bias=True,
        is_encoder_decoder=True, n_encoder_layers=2, encoder_seq=64,
        norm="layernorm", act="gelu", dtype="float32", remat=False,
    )
