"""gemma3-1b [dense] — 5:1 local(512-window):global, GQA kv=1, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig

ARCH_ID = "gemma3-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab_size=262144,
        attention="gqa", qkv_bias=False, rope_theta=1_000_000.0,
        sliding_window=512, local_global_pattern=(5, 1),
        norm="rmsnorm", act="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512,
        attention="gqa", sliding_window=32, local_global_pattern=(1, 1),
        norm="rmsnorm", act="gelu", dtype="float32", remat=False,
    )
