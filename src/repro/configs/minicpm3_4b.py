"""minicpm3-4b [dense] — MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import MLAConfig, ModelConfig

ARCH_ID = "minicpm3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=6400, vocab_size=73448,
        attention="mla", rope_theta=10_000.0,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
