"""granite-moe-3b-a800m [moe] — 40 experts top-8 (spec line; bracket cites the
granite-3.0-1b-a400m card which has 32 — we implement 40 per the assignment
spec line, see DESIGN.md §7). GQA kv=8, expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155,
        attention="gqa", qkv_bias=False, rope_theta=10_000.0,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, capacity_factor=1.25),
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512,
        attention="gqa",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=1.5),
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
