"""Paper-faithful CIFAR-analog model: small CNN with BatchNorm (ResNet9-style
channel progression, davidcpage/cifar10-fast inspired). Used by the SWAP
reproduction benchmarks (Tables 1/2/4, Figures 1-4) on synthetic image data;
exercises phase-3 batch-norm statistic recomputation, which the transformer
archs don't need."""
from repro.configs.base import ModelConfig

ARCH_ID = "cifar-cnn"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="cnn",
        n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
        attention="none", norm="layernorm",
        cnn_channels=(64, 128, 256, 256), n_classes=10, image_size=32,
        dtype="float32", remat=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="cnn",
        n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
        attention="none", norm="layernorm",
        cnn_channels=(16, 32), n_classes=10, image_size=16,
        dtype="float32", remat=False,
    )
