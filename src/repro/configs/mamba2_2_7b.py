"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "mamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=64,
        d_ff=0, vocab_size=50280,
        attention="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=256, n_heads=0, n_kv_heads=0, head_dim=32,
        d_ff=0, vocab_size=512,
        attention="none",
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk_size=64),
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
