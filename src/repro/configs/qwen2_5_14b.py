"""qwen2.5-14b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=13824, vocab_size=152064,
        attention="gqa", qkv_bias=True, rope_theta=1_000_000.0,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
        attention="gqa", qkv_bias=True, rope_theta=1_000_000.0,
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
