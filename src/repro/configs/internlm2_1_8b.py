"""internlm2-1.8b [dense] — GQA kv=8. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=92544,
        attention="gqa", qkv_bias=False, rope_theta=1_000_000.0,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        attention="gqa", rope_theta=1_000_000.0,
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
