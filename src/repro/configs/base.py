"""Configuration dataclasses for the SWAP framework.

Everything the launcher, the dry-run, and the SWAP controller need is
described by plain frozen dataclasses so configs are hashable (usable as
jit static args) and serializable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ARCH_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "cnn")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style capacity dispatch)."""

    n_experts: int = 0
    top_k: int = 0
    d_ff: int = 0                  # per-expert hidden width
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    n_shared_experts: int = 0      # always-on experts (deepseek-style); 0 = none
    shared_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # SSD head dim (P)
    n_groups: int = 1              # B/C groups (GVA-style)
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek/MiniCPM3-style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. One instance per assigned arch (full + smoke)."""

    name: str
    family: str                    # one of ARCH_FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention flavour
    attention: str = "gqa"         # "gqa" | "mla" | "none"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 -> full attention
    # local:global layer pattern, e.g. (5, 1) = 5 sliding-window layers then 1 global.
    # (0, 0) -> uniform layers.
    local_global_pattern: Tuple[int, int] = (0, 0)
    # M-RoPE (qwen2-vl): rope split into (temporal, height, width) sections.
    mrope_sections: Tuple[int, ...] = ()

    # family-specific blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (zamba2): one SHARED attention block applied every k mamba layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500        # stub frame-embedding count

    # vlm stub
    n_vision_tokens: int = 0       # patch embeds prepended to the sequence

    # norms / misc
    norm: str = "rmsnorm"          # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"              # "silu" | "gelu"
    dtype: str = "bfloat16"        # activation/compute dtype for lowering
    param_dtype: str = "float32"

    # implementation switches, resolved per backend by
    # repro.kernels.dispatch (see its table): "auto" picks the compiled
    # native kernel per backend (Mosaic on TPU, Triton on GPU, reference on
    # CPU); "pallas"/"mosaic"/"triton"/"reference"/"naive" force a path
    # (a forced lowering off its native backend runs interpreted).
    attention_impl: str = "auto"   # one of dispatch.KERNEL_IMPLS
    ssd_impl: str = "auto"         # one of dispatch.KERNEL_IMPLS
    # optional pinned tuning design points, (block_q, block_k, num_warps,
    # num_stages); () = consult the persisted tuning cache (the default).
    attention_design: Tuple[int, ...] = ()
    ssd_design: Tuple[int, ...] = ()
    attention_chunk: int = 512          # kv block for blockwise reference attn
    remat: bool = True                  # checkpoint each layer in train_step
    # remat policy: "full" recomputes everything; "dots" saves matmul
    # outputs (jax dots_with_no_batch_dims_saveable) — trades HBM capacity
    # for a large cut in recompute bytes/flops (§Perf iter 5).
    remat_policy: str = "dots"
    scan_layers: bool = True            # lax.scan over stacked layer params
    # pin the residual stream to batch-sharded at block boundaries; helped
    # nothing once the MoE-internal constraints existed and hurts some
    # dense-attention partitions — off by default (§Perf iter 3b).
    constrain_residual: bool = False
    # KV-cache storage: "" = activation dtype; "int8" = symmetric per
    # (token, head) quantization — halves the decode memory-roofline term
    # for attention archs (beyond-paper; GQA caches only).
    kv_cache_dtype: str = ""

    # CNN (paper-faithful CIFAR-analog model)
    cnn_channels: Tuple[int, ...] = ()
    n_classes: int = 0
    image_size: int = 32

    def __post_init__(self):
        if self.family not in ARCH_FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        # validate impl strings HERE, not at resolve time deep inside a
        # jitted trace (function-local import: this module stays jax-free
        # at import time for config-only tooling)
        from repro.kernels.dispatch import validate_impl
        validate_impl(self.attention_impl, "ModelConfig.attention_impl")
        validate_impl(self.ssd_impl, "ModelConfig.ssd_impl")
        for fld in ("attention_design", "ssd_design"):
            pin = getattr(self, fld)
            if pin and len(pin) != 4:
                raise ValueError(
                    f"ModelConfig.{fld} must be () or a 4-tuple (block_q, "
                    f"block_k, num_warps, num_stages); got {pin!r}")

    @property
    def d_head_q(self) -> int:
        if self.attention == "mla":
            return self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
        return self.head_dim

    # ---------------- parameter counting (for roofline MODEL_FLOPS) --------
    def param_count(self) -> int:
        """Total parameters (analytic, matches init to within ties/norms)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: only top_k experts count)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    if cfg.family == "cnn":
        # rough CNN count: conv 3x3 chains
        total, prev = 0, 3
        for c in cfg.cnn_channels:
            total += 3 * 3 * prev * c + 2 * c
            prev = c
        total += prev * cfg.n_classes
        return total

    d, v = cfg.d_model, cfg.vocab_size
    total = v * d                       # embed
    if not cfg.tie_embeddings:
        total += v * d                  # lm head

    def attn_params() -> int:
        if cfg.attention == "mla":
            m = cfg.mla
            qh = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qh
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += cfg.n_heads * m.v_head_dim * d
            return p
        hd = cfg.head_dim
        p = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
        p += cfg.n_heads * hd * d
        if cfg.qkv_bias:
            p += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        return p

    def mlp_params() -> int:
        return 3 * d * cfg.d_ff  # swiglu: wi, wg, wo

    def moe_params() -> int:
        m = cfg.moe
        n_e = m.top_k if active_only else m.n_experts
        p = d * m.n_experts                        # router (always)
        p += n_e * 3 * d * m.d_ff
        if m.n_shared_experts:
            p += m.n_shared_experts * 3 * d * m.shared_d_ff
        return p

    def ssm_params() -> int:
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)   # in_proj
        p += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)    # conv
        p += nh * 2                                            # A_log, D
        p += d_in * d                                          # out_proj
        return p

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params())
    elif cfg.family == "moe":
        total += cfg.n_layers * (attn_params() + moe_params())
    elif cfg.family == "ssm":
        total += cfg.n_layers * ssm_params()
    elif cfg.family == "hybrid":
        total += cfg.n_layers * ssm_params()
        total += attn_params() + mlp_params()      # ONE shared attention block
    elif cfg.family == "audio":
        total += cfg.n_layers * (2 * attn_params() + mlp_params())  # self+cross
        total += cfg.n_encoder_layers * (attn_params() + mlp_params())
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic decode); see DESIGN.md §4
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "zamba2-7b", "gemma3-1b")


def shape_applicable(arch_name: str, family: str, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return arch_name in LONG_CONTEXT_ARCHS or family in ("ssm", "hybrid")
    return True


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Optimization / schedules / SWAP
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScheduleConfig:
    """Piecewise-linear warmup + decay, or cyclic (for SWA sampling)."""

    kind: str = "warmup_linear"    # "warmup_linear" | "warmup_cosine" | "cyclic" | "const"
    peak_lr: float = 0.1
    warmup_steps: int = 0
    total_steps: int = 1000
    end_lr: float = 0.0
    cycle_steps: int = 0           # for "cyclic"
    min_lr: float = 0.0


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"              # "sgd" | "lars" | "adamw"
    momentum: float = 0.9
    nesterov: bool = True
    weight_decay: float = 5e-4
    # adamw
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    # lars
    trust_coefficient: float = 0.001
    # DEPRECATED: set PhaseConfig.precision / PrecisionPolicy.grad_dtype
    # instead. Still parsed (resolve_policy folds it into the policy, and
    # the cast now happens inside the precision step — after unscaling,
    # before the data-axis psum — rather than as a loose post-grad cast).
    grad_dtype: str = "float32"


@dataclass(frozen=True)
class PhaseConfig:
    """One SWAP phase (1 = large-batch sync, 2 = small-batch independent)."""

    batch_size: int = 512          # GLOBAL batch (phase 2: per worker)
    schedule: ScheduleConfig = field(default_factory=ScheduleConfig)
    max_steps: int = 1000
    stop_accuracy: float = 1.01    # phase-1 early exit threshold τ (>1 = never)
    accuracy_ema: float = 0.9      # smoothing for the stopping criterion
    # numerics (repro.train.precision): PrecisionPolicy preset name —
    # "float32" | "bfloat16" | "float16" (f16 adds dynamic loss scaling
    # with inf/nan step skipping). Phase 2 should stay "float32" so the
    # averaging/generalization claims are untouched; phase 1 is where the
    # large-batch compute lives.
    precision: str = "float32"
    # microbatch accumulation: split each global batch into this many
    # sequential microbatches inside the step (inner lax.scan) — identical
    # effective batch size for the gradient, ~grad_accum_steps× smaller
    # activation memory, so phase-1 batches larger than device memory
    # still run. Caveat: BatchNorm statistics become per-microbatch (see
    # docs/training.md §Precision & accumulation); fused-step equivalence
    # holds exactly only for stateless models.
    grad_accum_steps: int = 1


@dataclass(frozen=True)
class SWAPConfig:
    """The paper's algorithm (Algorithm 1)."""

    n_workers: int = 8
    phase1: PhaseConfig = field(default_factory=PhaseConfig)
    phase2: PhaseConfig = field(default_factory=PhaseConfig)
    # phase-3 batch-norm statistic recompute passes (no-op for norm-stat-free models)
    bn_recompute_batches: int = 8
    bn_recompute_batch_size: int = 256
    seed: int = 0
    # periodic TrainState snapshots (repro.checkpoint.state): every N steps,
    # landing on epoch-aligned chunk boundaries; 0 / "" disables. Resume via
    # SWAP.run(resume=True) restarts bit-exactly mid-phase-1 or mid-phase-2.
    checkpoint_dir: str = ""
    checkpoint_every: int = 0


@dataclass(frozen=True)
class SWAConfig:
    """Sequential SWA baseline (Izmailov et al. 2018) for Table-4 comparisons."""

    n_samples: int = 8             # models averaged
    cycle_steps: int = 100         # steps between samples (cyclic LR period)
    schedule: ScheduleConfig = field(default_factory=lambda: ScheduleConfig(kind="cyclic"))
    batch_size: int = 512
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = None
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    swap: SWAPConfig = field(default_factory=SWAPConfig)
    mesh: MeshConfig = field(default_factory=lambda: SINGLE_POD)
    seq_len: int = 4096
    eval_batches: int = 4
    eval_batch_size: int = 256
    log_every: int = 10
    checkpoint_dir: str = ""
    data_seed: int = 1234


def replace(cfg, **kw):
    """dataclasses.replace that tolerates nested dotted keys ('moe.top_k')."""
    direct = {k: v for k, v in kw.items() if "." not in k}
    nested = {k: v for k, v in kw.items() if "." in k}
    out = dataclasses.replace(cfg, **direct) if direct else cfg
    for key, val in nested.items():
        head, rest = key.split(".", 1)
        out = dataclasses.replace(out, **{head: replace(getattr(out, head), **{rest: val})})
    return out
