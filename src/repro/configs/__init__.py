from repro.configs.base import (
    ARCH_FAMILIES, LONG_CONTEXT_ARCHS, MLAConfig, MeshConfig, ModelConfig,
    MoEConfig, MULTI_POD, OptimizerConfig, PhaseConfig, SHAPES, SINGLE_POD,
    SSMConfig, ScheduleConfig, ShapeConfig, SWAConfig, SWAPConfig, TrainConfig,
    replace, shape_applicable,
)
from repro.configs.registry import (
    ASSIGNED_ARCHS, all_configs, get_config, get_smoke_config, list_archs,
)
