from repro.configs.base import (
    ARCH_FAMILIES, LONG_CONTEXT_ARCHS, MULTI_POD, SHAPES, SINGLE_POD,
    MeshConfig, MLAConfig, ModelConfig, MoEConfig, OptimizerConfig,
    PhaseConfig, ScheduleConfig, ShapeConfig, SSMConfig, SWAConfig,
    SWAPConfig, TrainConfig, replace, shape_applicable,
)
from repro.configs.registry import (
    ASSIGNED_ARCHS, all_configs, get_config, get_smoke_config, list_archs,
)
