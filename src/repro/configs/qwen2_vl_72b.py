"""qwen2-vl-72b [vlm] — M-RoPE (temporal/height/width rope sections), dynamic
resolution. Vision encoder is a STUB: input_specs provides precomputed patch
embeddings merged at the head of the sequence. [arXiv:2409.12191]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab_size=152064,
        attention="gqa", qkv_bias=True, rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),   # halves of head_dim: 16+24+24 = 64
        n_vision_tokens=256,
        norm="rmsnorm", act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512,
        attention="gqa", qkv_bias=True,
        mrope_sections=(8, 12, 12),
        n_vision_tokens=16,
        norm="rmsnorm", act="silu", dtype="float32", remat=False,
    )
