"""SWAP training launcher.

Runs the full three-phase SWAP schedule on an LM architecture (smoke-sized
by default so it executes on this host; full configs are exercised via the
dry-run). The same controller drives the TPU path: phase 1 on the
('data','model') mesh, phase 2 on ('worker','data','model').

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      [--full] [--workers 4] [--phase1-steps 150] [--phase2-steps 60] \
      [--stop-acc 0.6] [--optimizer sgd|lars|adamw] [--save out.ckpt] \
      [--phase1-precision bfloat16] [--grad-accum 4] \
      [--checkpoint-dir ckpts/ --checkpoint-every 50] [--resume] \
      [--mesh worker:4,data:2] [--elastic-deadline 30] [--lost-workers 3]

Large phase-1 batches: --phase1-precision bfloat16 computes the forward/
backward in bf16 with f32 master weights; --grad-accum k runs each global
batch as k sequential microbatches (same effective batch, ~k× less
activation memory). See docs/training.md §Precision & accumulation.

Long jobs: pass --checkpoint-dir/--checkpoint-every for periodic TrainState
snapshots (epoch-aligned), then relaunch with --resume to continue
bit-exactly from the newest snapshot — mid-phase-1 or mid-phase-2.

Distribution: the --mesh/--workers/--phase2-engine/--elastic-*/
--coordinator flag group is the unified ``repro.dist.DistConfig`` surface
(``--dist-config file.json`` loads one, ``--dump-dist-config`` records the
resolved config for exact replay); multi-host launches pass
--coordinator/--num-processes/--process-id per host and each host then
loads only its shard of every phase-1 batch. --lost-workers simulates
worker loss for the elastic phase-3 averaging drill (docs/training.md
§Elastic averaging).

Resilience (docs/resilience.md): --heartbeat-dir switches elastic
arrivals from the simulated --lost-workers surface to REAL per-worker
heartbeat beacons (this in-process launcher beats every live worker at
each phase-2 chunk boundary; --lost-workers now marks workers that never
beat, so the monitor — not a hand-fed timestamp — declares them dead).
--supervise N wraps both phases in a PhaseSupervisor with an N-retry
budget: divergence rolls back to the last verified checkpoint, and a
worker whose beacon goes stale mid-phase-2 is dropped and the phase
resumes with the survivors.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.checkpoint.io import save_pytree
from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig, ScheduleConfig,
                                SWAPConfig)
from repro.core.adapters import LMAdapter
from repro.core.swap import SWAP
from repro.data.pipeline import Loader, make_markov_lm
from repro.dist.config import DistConfig, add_dist_args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.list_archs())
    ap.add_argument("--full", action="store_true",
                    help="use the full (assigned) config instead of smoke")
    add_dist_args(ap)
    ap.add_argument("--lost-workers", default="",
                    help="comma-separated worker indices that never report "
                         "in phase 3 (elastic-averaging drill; needs "
                         "--elastic-deadline > 0)")
    ap.add_argument("--phase1-steps", type=int, default=150)
    ap.add_argument("--phase2-steps", type=int, default=60)
    ap.add_argument("--phase1-batch", type=int, default=256)
    ap.add_argument("--phase2-batch", type=int, default=32)
    ap.add_argument("--stop-acc", type=float, default=0.55)
    ap.add_argument("--peak-lr", type=float, default=0.5)
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "lars", "adamw"])
    ap.add_argument("--phase1-precision", default="float32",
                    choices=["float32", "bfloat16", "float16"],
                    help="phase-1 PrecisionPolicy preset (bf16 compute + "
                         "f32 master weights; f16 adds dynamic loss "
                         "scaling with inf/nan step skipping)")
    ap.add_argument("--phase2-precision", default="float32",
                    choices=["float32", "bfloat16", "float16"],
                    help="phase-2 preset; keep f32 (default) to leave the "
                         "averaging/generalization claims untouched")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="phase-1 microbatch accumulation: split each "
                         "global batch into this many sequential "
                         "microbatches (identical effective batch size)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default="")
    ap.add_argument("--json-out", default="")
    ap.add_argument("--checkpoint-dir", default="",
                    help="directory for periodic TrainState snapshots")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot cadence in steps (epoch-aligned); 0 = off")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest snapshot in "
                         "--checkpoint-dir (bit-exact, mid-phase)")
    ap.add_argument("--supervise", type=int, default=0, metavar="RETRIES",
                    help="wrap both phases in a resilience.PhaseSupervisor "
                         "with this retry budget (0 = unsupervised); "
                         "divergence rolls back to the last verified "
                         "checkpoint, stale-heartbeat workers are dropped")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    dist = DistConfig.from_args(args, n_workers_default=4)
    # multi-host: join the jax.distributed cluster BEFORE any device query
    dist.initialize()
    if args.dump_dist_config:
        dist.to_json(args.dump_dist_config)
        print(f"wrote resolved DistConfig to {args.dump_dist_config}")
    lost = [int(w) for w in args.lost_workers.split(",") if w.strip()]
    if lost and not dist.elastic:
        raise SystemExit("--lost-workers needs --elastic-deadline > 0 "
                         "(a strict phase-3 barrier cannot drop workers)")
    worker_arrivals = None
    monitor = None
    phase2_hooks = []
    if dist.heartbeats:
        # real liveness replaces the simulated-arrival path: every worker
        # this launcher drives beats at each phase-2 chunk boundary, a
        # --lost-workers worker simply never beats, and phase 3 reads
        # arrival lateness off beacon staleness via the monitor
        from repro.dist.heartbeat import (HeartbeatMonitor, HeartbeatWriter,
                                          beat_on_chunk)
        writers = [HeartbeatWriter(dist.heartbeat_dir, w,
                                   interval_s=dist.heartbeat_interval_s)
                   for w in range(dist.n_workers) if w not in lost]
        for wtr in writers:
            wtr.beat()                       # everyone alive at launch
        monitor = HeartbeatMonitor(dist.heartbeat_dir, dist.n_workers,
                                   timeout_s=dist.resolved_heartbeat_timeout)
        phase2_hooks.append(beat_on_chunk(writers))
    elif lost:
        worker_arrivals = [float("inf") if w in lost else 0.0
                           for w in range(dist.n_workers)]
    supervisor = None
    if args.supervise > 0:
        from repro.resilience import PhaseSupervisor, SupervisorConfig
        supervisor = PhaseSupervisor(
            SupervisorConfig(max_retries=args.supervise), monitor=monitor)

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    if cfg.family == "cnn":
        raise SystemExit("use benchmarks/table1_cifar10.py for the CNN")

    data = make_markov_lm(args.seed, vocab=min(cfg.vocab_size, 512),
                          n_train=4096, n_test=1024, seq_len=args.seq_len)
    train = {"tokens": data["train_tokens"] % cfg.vocab_size,
             "labels": data["train_labels"] % cfg.vocab_size}
    test_loader = Loader({"tokens": data["test_tokens"] % cfg.vocab_size,
                          "labels": data["test_labels"] % cfg.vocab_size},
                         256)

    lr_small = args.peak_lr * args.phase2_batch / args.phase1_batch
    opt = OptimizerConfig(kind=args.optimizer,
                          weight_decay=5e-4 if args.optimizer != "adamw"
                          else 0.01)
    if args.optimizer == "adamw":
        args.peak_lr, lr_small = 3e-3, 1e-3
    adapter = LMAdapter(cfg, opt)
    swap_cfg = SWAPConfig(
        n_workers=dist.n_workers,
        phase1=PhaseConfig(
            batch_size=args.phase1_batch, max_steps=args.phase1_steps,
            stop_accuracy=args.stop_acc,
            precision=args.phase1_precision,
            grad_accum_steps=args.grad_accum,
            schedule=ScheduleConfig(kind="warmup_linear", peak_lr=args.peak_lr,
                                    warmup_steps=args.phase1_steps // 5,
                                    total_steps=args.phase1_steps)),
        phase2=PhaseConfig(
            batch_size=args.phase2_batch, max_steps=args.phase2_steps,
            precision=args.phase2_precision,
            schedule=ScheduleConfig(kind="warmup_linear", peak_lr=lr_small,
                                    warmup_steps=0,
                                    total_steps=args.phase2_steps)),
        seed=args.seed, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)

    n_params = cfg.param_count()
    swap = SWAP(adapter, swap_cfg, train, test_loader, dist=dist,
                supervisor=supervisor)
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"workers={dist.n_workers} "
          f"engine={dist.resolved_engine(swap.mesh)}"
          + (f" mesh={'x'.join(map(str, dist.mesh_shape))}"
             if dist.mesh_shape else ""))
    t0 = time.time()
    res = swap.run(jax.random.PRNGKey(args.seed), resume=args.resume,
                   worker_arrivals=worker_arrivals,
                   phase2_hooks=phase2_hooks, heartbeats=monitor)
    out = {k: v for k, v in res.items()
           if isinstance(v, (int, float, list)) and k != "phase1_log"}
    out["wall_s"] = time.time() - t0
    print(json.dumps({k: v for k, v in out.items()
                      if not isinstance(v, list)}, indent=1))
    print(f"worker accs: {['%.4f' % a for a in res['worker_test_accs']]}")
    if dist.elastic:
        print(f"elastic: {res['phase2_live_workers']}/{dist.n_workers} "
              f"workers in the average, live mask "
              f"{res['worker_live_mask']}")
    for ev in res.get("recovery_events", []):
        print(f"recovery: {ev['kind']} in {ev['tag']} (attempt "
              f"{ev['attempt']}) -> resumed from {ev['restored_from']} at "
              f"step {ev['restored_step']}")
    print(f"SWAP: before avg {res['before_avg_test_acc']:.4f} -> "
          f"after avg {res['after_avg_test_acc']:.4f}")
    if args.save:
        save_pytree(args.save, res["final_bundle"]["params"])
        print(f"saved averaged model to {args.save}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
