"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; everything here just asks for whatever devices exist.
"""
from __future__ import annotations

import jax

import repro.dist  # noqa: F401  (compat shims: AxisType / axis_types kwarg)


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Phase-1 (synchronous large-batch) mesh: one TPU v5e pod is (16, 16)
    = 256 chips; two pods stack a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_worker_mesh(n_workers: int = 8, *, multi_pod: bool = False):
    """Phase-2 mesh: the data axis is split into `n_workers` independent
    blocks; each worker keeps FSDP/tensor parallelism inside its block.
    512 = 8 workers x 4 data x 16 model (workers never straddle pods for
    n_workers >= n_pods since the worker axis is outermost in device order).
    """
    total = 512 if multi_pod else 256
    model = 16
    data = total // (n_workers * model)
    if data < 1:
        raise ValueError(f"{n_workers} workers don't fit {total} chips")
    return _mk((n_workers, data, model), ("worker", "data", "model"))


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host has (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model_parallel, n)
    return _mk((n // model, model), ("data", "model"))
