"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      [--engine {loop,compiled}] [--batch 8] [--prompt-len 64] \
      [--new-tokens 32] [--ckpt model.ckpt]

Two decode engines:
  * ``loop`` — one jitted decode dispatch per Python iteration (the
    pre-compiled-engine baseline).
  * ``compiled`` — the whole decode fused in ONE jit (``lax.scan`` over
    steps, like repro.serve.compiled): a single bulk host transfer of the
    (B, new_tokens) block instead of per-step dispatch.

Throughput is reported for prefill and decode SEPARATELY (prompt tok/s vs
generated tok/s) plus an overall rate that includes prefill cost — the old
single ``tokens_per_s`` silently excluded prefill from throughput claims.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_pytree
from repro.configs import registry
from repro.models.model import Model


def _decode_loop(model, params, cache, tok, S, new_tokens, greedy, rng):
    """Per-step loop (baseline engine): one jitted dispatch per token."""
    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i))
    tokens = []
    for i in range(new_tokens):
        tokens.append(tok)
        logits, cache = decode(params, cache, tok, S + i)
        if greedy or rng is None:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    return jnp.concatenate(tokens, axis=1)


def _decode_compiled(model, params, cache, tok, S, new_tokens, greedy, rng):
    """All decode steps fused under one jit; one bulk host transfer."""
    use_rng = not greedy and rng is not None
    key0 = rng if use_rng else jax.random.PRNGKey(0)

    @jax.jit
    def fused(cache, tok, key):
        def body(carry, i):
            cache, tok, key = carry
            emit = tok[:, 0]
            logits, cache = model.decode(params, cache, tok, i)
            if use_rng:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, logits)[:, None]
            else:
                nxt = jnp.argmax(logits, -1)[:, None]
            return (cache, nxt.astype(jnp.int32), key), emit

        (_, _, _), toks = jax.lax.scan(
            body, (cache, tok, key), jnp.arange(S, S + new_tokens))
        return toks.T                                       # (B, new)

    out = fused(cache, tok, key0)
    jax.block_until_ready(out)
    return out


def generate(model: Model, params, prompts, new_tokens: int,
             extras=None, greedy: bool = True, rng=None,
             engine: str = "loop"):
    """Batched greedy/sampled generation. prompts: (B, S) int32.
    ``engine``: "loop" (per-step dispatch) or "compiled" (fused scan);
    both produce identical greedy tokens."""
    if engine not in ("loop", "compiled"):
        raise ValueError(f"unknown engine {engine!r}")
    extras = extras or {}
    B, S = prompts.shape
    cache_len = S + new_tokens
    prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len=cache_len,
                                                 **extras))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    decode = _decode_compiled if engine == "compiled" else _decode_loop
    t0 = time.perf_counter()
    out = decode(model, params, cache, tok, S, new_tokens, greedy, rng)
    t_decode = time.perf_counter() - t0

    gen = B * new_tokens
    total = t_prefill + t_decode
    return out, {
        "engine": engine,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        # split rates: prompt tokens through prefill, generated through
        # decode — and an overall rate that does NOT hide prefill cost
        "prefill_tokens_per_s": B * S / max(t_prefill, 1e-9),
        "decode_tokens_per_s": gen / max(t_decode, 1e-9),
        "tokens_per_s": gen / max(total, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="compiled",
                    choices=["loop", "compiled"],
                    help="decode engine: fused-scan (compiled) or the "
                         "per-step python loop baseline")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (halves cache memory)")
    args = ap.parse_args()

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    if args.kv_int8:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params = load_pytree(args.ckpt, params)
        print(f"restored {args.ckpt}")

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), model.dtype)
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), model.dtype)

    out, stats = generate(model, params, prompts, args.new_tokens,
                          extras=extras, engine=args.engine)
    print(f"arch={cfg.name} engine={args.engine} batch={B} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms "
          f"({stats['prefill_tokens_per_s']:.1f} prompt tok/s), decode "
          f"{stats['decode_s']*1e3:.1f} ms "
          f"({stats['decode_tokens_per_s']:.1f} tok/s), overall "
          f"{stats['tokens_per_s']:.1f} tok/s incl. prefill")
    print("first sequences:", out[:2, :16].tolist())


if __name__ == "__main__":
    main()
