"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      [--batch 8] [--prompt-len 64] [--new-tokens 32] [--ckpt model.ckpt]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_pytree
from repro.configs import registry
from repro.models.model import Model


def generate(model: Model, params, prompts, new_tokens: int,
             extras=None, greedy: bool = True, rng=None):
    """Batched greedy/sampled generation. prompts: (B, S) int32."""
    extras = extras or {}
    B, S = prompts.shape
    cache_len = S + new_tokens
    prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len=cache_len,
                                                 **extras))
    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        tokens.append(tok)
        logits, cache = decode(params, cache, tok, S + i)
        if greedy or rng is None:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(tokens, axis=1)
    return out, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tokens_per_s": B * new_tokens / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (halves cache memory)")
    args = ap.parse_args()

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    if args.kv_int8:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params = load_pytree(args.ckpt, params)
        print(f"restored {args.ckpt}")

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), model.dtype)
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), model.dtype)

    out, stats = generate(model, params, prompts, args.new_tokens,
                          extras=extras)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms, decode "
          f"{stats['decode_s']*1e3:.1f} ms "
          f"({stats['tokens_per_s']:.1f} tok/s)")
    print("first sequences:", out[:2, :16].tolist())


if __name__ == "__main__":
    main()
