"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      [--engine {loop,compiled}] [--batch 8] [--prompt-len 64] \
      [--new-tokens 32] [--ckpt model.ckpt]

Two decode engines:
  * ``loop`` — one jitted decode dispatch per Python iteration (the
    pre-compiled-engine baseline).
  * ``compiled`` — the whole decode fused in ONE jit (``lax.scan`` over
    steps, like repro.serve.compiled): a single bulk host transfer of the
    (B, new_tokens) block instead of per-step dispatch.

Throughput is reported for prefill and decode SEPARATELY (prompt tok/s vs
generated tok/s) plus an overall rate that includes prefill cost — the old
single ``tokens_per_s`` silently excluded prefill from throughput claims.

Live-following mode — the consumer half of the continuous train→serve
loop (``repro.serve.publish``):

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --follow ckpts/ [--follow-timeout 10]

tails ``ckpts/`` for atomic publish snapshots written by a
``WeightPublisher`` (e.g. a training run with live publishing enabled),
hot-swaps each new weight generation into a running
``CompiledServingEngine`` without dropping in-flight requests, and serves
a continuous synthetic request stream until no new generation appears for
``--follow-timeout`` seconds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_pytree
from repro.configs import registry
from repro.dist.config import DistConfig, add_dist_args
from repro.models.model import Model


def _decode_loop(model, params, cache, tok, S, new_tokens, greedy, rng):
    """Per-step loop (baseline engine): one jitted dispatch per token."""
    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i))
    tokens = []
    for i in range(new_tokens):
        tokens.append(tok)
        logits, cache = decode(params, cache, tok, S + i)
        if greedy or rng is None:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    return jnp.concatenate(tokens, axis=1)


def _decode_compiled(model, params, cache, tok, S, new_tokens, greedy, rng):
    """All decode steps fused under one jit; one bulk host transfer."""
    use_rng = not greedy and rng is not None
    key0 = rng if use_rng else jax.random.PRNGKey(0)

    @jax.jit
    def fused(cache, tok, key):
        def body(carry, i):
            cache, tok, key = carry
            emit = tok[:, 0]
            logits, cache = model.decode(params, cache, tok, i)
            if use_rng:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, logits)[:, None]
            else:
                nxt = jnp.argmax(logits, -1)[:, None]
            return (cache, nxt.astype(jnp.int32), key), emit

        (_, _, _), toks = jax.lax.scan(
            body, (cache, tok, key), jnp.arange(S, S + new_tokens))
        return toks.T                                       # (B, new)

    out = fused(cache, tok, key0)
    jax.block_until_ready(out)
    return out


def generate(model: Model, params, prompts, new_tokens: int,
             extras=None, greedy: bool = True, rng=None,
             engine: str = "loop"):
    """Batched greedy/sampled generation. prompts: (B, S) int32.
    ``engine``: "loop" (per-step dispatch) or "compiled" (fused scan);
    both produce identical greedy tokens."""
    if engine not in ("loop", "compiled"):
        raise ValueError(f"unknown engine {engine!r}")
    extras = extras or {}
    B, S = prompts.shape
    cache_len = S + new_tokens
    prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len=cache_len,
                                                 **extras))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    decode = _decode_compiled if engine == "compiled" else _decode_loop
    t0 = time.perf_counter()
    out = decode(model, params, cache, tok, S, new_tokens, greedy, rng)
    t_decode = time.perf_counter() - t0

    gen = B * new_tokens
    total = t_prefill + t_decode
    return out, {
        "engine": engine,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        # split rates: prompt tokens through prefill, generated through
        # decode — and an overall rate that does NOT hide prefill cost
        "prefill_tokens_per_s": B * S / max(t_prefill, 1e-9),
        "decode_tokens_per_s": gen / max(t_decode, 1e-9),
        "tokens_per_s": gen / max(total, 1e-9),
    }


def follow(model: Model, cfg, params, args) -> dict:
    """Serve a continuous synthetic request stream while tailing
    ``args.follow`` for publish snapshots; hot-swap each new weight
    generation into the live engine without dropping in-flight requests.

    Exits after ``--follow-timeout`` seconds with no new generation (the
    deadline resets on every pickup). Returns a per-generation report.
    """
    from repro.serve.compiled import CompiledServingEngine
    from repro.serve.engine import Request
    from repro.serve.publish import PublishFollower

    max_seq = args.prompt_len + args.new_tokens + 8
    engine = CompiledServingEngine(
        model, params, max_batch=args.batch, max_seq=max_seq,
        decode_block=args.decode_block, prefill_buckets=[args.prompt_len],
        kv_layout=args.kv_layout, page_size=args.page_size,
        admit_timeout_s=args.admit_timeout or None,
        dist=args.dist if args.dist.mesh_shape else None)
    follower = PublishFollower(args.follow, template=params)
    upd = follower.poll()
    if upd is not None:                       # seed from the newest publish
        gen, new = upd
        engine.publish(new, generation=gen)
        print(f"seeded from publish generation {gen}")
    engine.warmup(dual=True)                  # compile both decode programs

    key = jax.random.PRNGKey(args.seed + 1)
    rid = 0
    requests: list = []

    def _feed():
        """Keep every slot busy so swaps land on a loaded engine."""
        nonlocal rid
        while len(engine.waiting) + engine.active < args.batch:
            prompt = jax.random.randint(
                jax.random.fold_in(key, rid), (args.prompt_len,), 0,
                cfg.vocab_size, dtype=jnp.int32)
            req = Request(rid=rid, prompt=prompt,
                          max_new_tokens=args.new_tokens)
            requests.append(req)
            engine.submit(req)
            rid += 1

    pickups = 0
    deadline = time.time() + args.follow_timeout
    while time.time() < deadline:
        upd = follower.poll()
        if upd is not None:
            gen, new = upd
            engine.publish(new, generation=gen)
            applied = "applied" if engine.generation == gen else "deferred"
            print(f"picked up generation {gen} ({applied}); "
                  f"{engine.active} requests in flight")
            pickups += 1
            deadline = time.time() + args.follow_timeout
        _feed()
        engine.step()
    while engine.active or engine.waiting:    # finish what was admitted
        engine.step()

    per_gen: dict = {}
    for req in requests:
        if req.done:
            e = per_gen.setdefault(req.generation, {"requests": 0,
                                                    "tokens": 0})
            e["requests"] += 1
            e["tokens"] += len(req.generated)
    st = engine.stats
    assert st["decode_transfers"] == st["decode_calls"], \
        "publish broke the single-transfer-per-decode-call invariant"
    return {"pickups": pickups, "per_generation": per_gen, "stats": st}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="compiled",
                    choices=["loop", "compiled"],
                    help="decode engine: fused-scan (compiled) or the "
                         "per-step python loop baseline")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (4x tokens per cache "
                         "byte vs f32)")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "dense", "paged"],
                    help="compiled-engine KV layout in --follow mode: "
                         "paged allocates cache pages on demand from a "
                         "shared pool (auto = paged when the arch "
                         "supports it)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page for --kv-layout paged")
    ap.add_argument("--follow", default="",
                    help="live-follow a publish directory: hot-swap new "
                         "weight generations into a running engine while "
                         "serving (see repro.serve.publish)")
    ap.add_argument("--follow-timeout", type=float, default=10.0,
                    help="exit --follow mode after this many seconds "
                         "without a new generation")
    ap.add_argument("--decode-block", type=int, default=4,
                    help="fused decode steps per host call in --follow")
    ap.add_argument("--admit-timeout", type=float, default=0.0,
                    help="bound (seconds) on how long a request may wait "
                         "for admission before being rejected instead of "
                         "holding the queue on an exhausted page pool "
                         "(0 = wait indefinitely)")
    add_dist_args(ap)
    args = ap.parse_args()
    args.dist = DistConfig.from_args(args)
    args.dist.initialize()
    if args.dump_dist_config:
        args.dist.to_json(args.dump_dist_config)
        print(f"wrote resolved DistConfig to {args.dump_dist_config}")

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    if args.kv_int8:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params = load_pytree(args.ckpt, params)
        print(f"restored {args.ckpt}")

    if args.follow:
        report = follow(model, cfg, params, args)
        print(f"follow mode done: {report['pickups']} generation pickups")
        for gen in sorted(report["per_generation"]):
            e = report["per_generation"][gen]
            print(f"  generation {gen}: {e['requests']} requests, "
                  f"{e['tokens']} tokens")
        st = report["stats"]
        print(f"decode_calls={st['decode_calls']} "
              f"decode_transfers={st['decode_transfers']} "
              f"publish_swaps={st['publish_swaps']} "
              f"dual_decode_calls={st['dual_decode_calls']}")
        return

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), model.dtype)
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), model.dtype)

    out, stats = generate(model, params, prompts, args.new_tokens,
                          extras=extras, engine=args.engine)
    print(f"arch={cfg.name} engine={args.engine} batch={B} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms "
          f"({stats['prefill_tokens_per_s']:.1f} prompt tok/s), decode "
          f"{stats['decode_s']*1e3:.1f} ms "
          f"({stats['decode_tokens_per_s']:.1f} tok/s), overall "
          f"{stats['tokens_per_s']:.1f} tok/s incl. prefill")
    print("first sequences:", out[:2, :16].tolist())


if __name__ == "__main__":
    main()
