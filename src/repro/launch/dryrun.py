import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO allocation (ShapeDtypeStruct inputs, AOT
compile only). Emits the roofline raw terms per combination:

  flops/bytes per device   from compiled.cost_analysis()
  collective bytes         parsed from post-SPMD HLO (per kind)
  memory_analysis          argument/output/temp bytes per device

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch qwen2.5-14b ...] [--shape train_4k ...] \
      [--mesh single|multi|both] [--phase2] [--out results/dryrun.json]
      [--skip-existing]

Phase-2 mode lowers the SWAP worker-ensemble step on the
('worker','data','model') mesh and ASSERTS no collective spans two workers
(the paper's "no synchronization between workers" property, checked in HLO).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, registry, shape_applicable
from repro.configs.base import (
    ModelConfig, OptimizerConfig, ScheduleConfig, ShapeConfig,
)
from repro.core.schedules import schedule_fn
from repro.dist.sharding import (
    assert_no_cross_worker_collectives, batch_shardings, cache_shardings,
    collective_bytes, param_shardings, set_mesh,
)
from repro.launch.mesh import make_production_mesh, make_worker_mesh
from repro.models.model import Model
from repro.train.precision import resolve_policy
from repro.train.steps import make_lm_train_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs = {}
    if shape.kind in ("train",):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token, cache of length S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D per train step (fwd+bwd), 2·N_active·D per inference
    token — the roofline's useful-compute numerator."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def _jit_for_shape(model: Model, cfg: ModelConfig, shape: ShapeConfig, mesh,
                   precision: str = "float32", grad_accum_steps: int = 1):
    """Build (jitted_fn, example_args) for the step this shape exercises."""
    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(mesh, params_shape)
    b_sh = batch_shardings(mesh, specs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(kind="sgd")
        opt_init, train_step = make_lm_train_step(
            model, opt_cfg, schedule_fn(ScheduleConfig(kind="const")),
            policy=resolve_policy(precision, opt_cfg),
            grad_accum_steps=grad_accum_steps)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        o_sh = param_shardings(mesh, opt_shape)
        fn = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh, repl),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))
        args = (params_shape, opt_shape, specs,
                jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(
                params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                frames=batch.get("frames"))
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return fn, (params_shape, specs)

    # decode
    cache_shape = jax.eval_shape(
        lambda: model.empty_cache(shape.global_batch, shape.seq_len))
    c_sh = cache_shardings(mesh, cache_shape, shape.global_batch)

    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    fn = jax.jit(decode_step,
                 in_shardings=(p_sh, c_sh, b_sh["tokens"], repl),
                 out_shardings=(None, c_sh),
                 donate_argnums=(1,))
    args = (params_shape, cache_shape, specs["tokens"],
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args


def _terms_from_compiled(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # JAX 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def roofline_extrapolated(arch: str, shape: ShapeConfig, mesh,
                          cfg: ModelConfig, precision: str = "float32",
                          grad_accum_steps: int = 1) -> dict:
    """XLA's cost_analysis counts a scan body ONCE (trip count ignored), so
    the production scan-lowered program under-reports flops/bytes/collective
    bytes. We recover exact totals by lowering two small UNROLLED variants —
    tail + 1 unit and tail + 2 units — and extrapolating linearly:

        term(n_units) = t1 + (n_units - 1) * (t2 - t1)

    The delta (t2 - t1) is exactly one pattern-unit's contribution (incl.
    its per-unit gradient all-reduce share); t1 carries embed/head/tail.
    Validated against a full unroll in tests/test_dryrun.py."""
    import dataclasses as dc
    unit_len = len(Model(cfg).unit_kinds)
    tail = cfg.n_layers % unit_len
    n_units = cfg.n_layers // unit_len

    def probe(k_units: int) -> dict:
        vcfg = dc.replace(cfg, n_layers=k_units * unit_len + tail,
                          scan_layers=False)
        vmodel = Model(vcfg)
        # set_mesh here, not at the caller: logical_constraint() resolves
        # against the ambient mesh and silently no-ops without it — which
        # would probe an unconstrained (partial-sum-heavy) program.
        with set_mesh(mesh):
            fn, args = _jit_for_shape(vmodel, vcfg, shape, mesh,
                                      precision=precision,
                                      grad_accum_steps=grad_accum_steps)
            return _terms_from_compiled(fn.lower(*args).compile())

    if n_units <= 8:
        # cheap enough to lower the exact unrolled program
        t = probe(n_units)
        t["per_unit"] = {}
        return t

    # XLA's per-unit cost drifts linearly with depth (live-range growth),
    # so fit a + b·k + c·k² through k = 2, 4, 6 units (k=1 programs get
    # special-cased by XLA optimizations and poison the fit); validated to
    # <0.1% against full unrolls in tests/test_dryrun.py.
    t2, t4, t6 = probe(2), probe(4), probe(6)

    def fit(f2, f4, f6, n):
        c = ((f6 - f4) - (f4 - f2)) / 8.0
        b = (f4 - f2) / 2.0 - 6.0 * c
        a = f2 - 2.0 * b - 4.0 * c
        return a + b * n + c * n * n

    out = {key: fit(t2[key], t4[key], t6[key], n_units)
           for key in ("flops", "bytes", "coll")}
    kinds = set(t2["coll_by_kind"]) | set(t4["coll_by_kind"]) \
        | set(t6["coll_by_kind"])
    out["coll_by_kind"] = {
        k: fit(t2["coll_by_kind"].get(k, 0), t4["coll_by_kind"].get(k, 0),
               t6["coll_by_kind"].get(k, 0), n_units) for k in kinds}
    out["per_unit"] = {k: (t4[k] - t2[k]) / 2.0
                       for k in ("flops", "bytes", "coll")}
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str,
            phase2: bool = False, n_workers: int = 8,
            precision: str = "float32", grad_accum_steps: int = 1,
            phase2_engine: str = "programs") -> dict:
    cfg = registry.get_config(arch)
    if precision not in ("float32", "", "f32", "fp32"):
        # thread the compute dtype through the model's per-matmul casts,
        # same as the LM adapter's training path
        import dataclasses as dc
        cfg = dc.replace(
            cfg, dtype=resolve_policy(precision).compute_dtype)
    shape = SHAPES[shape_name]
    if phase2_engine not in ("programs", "sharded"):
        raise ValueError(f"phase2_engine must be 'programs' or 'sharded', "
                         f"got {phase2_engine!r}")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "phase2": phase2, "status": "ok",
           "precision": precision or "float32",
           "grad_accum_steps": grad_accum_steps}
    if phase2:
        rec["phase2_engine"] = phase2_engine
    if not shape_applicable(arch, cfg.family, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §4)")
        return rec
    if phase2 and shape.kind != "train":
        rec["status"] = "skipped"
        rec["reason"] = "phase-2 ensemble applies to training only"
        return rec

    multi = mesh_kind == "multi"
    if phase2:
        mesh = make_worker_mesh(n_workers, multi_pod=multi)
    else:
        mesh = make_production_mesh(multi_pod=multi)
    rec["mesh_shape"] = dict(zip(mesh.axis_names,
                                 [int(mesh.shape[a]) for a in mesh.axis_names]))
    n_dev = mesh.devices.size
    model = Model(cfg)

    t0 = time.perf_counter()
    if phase2 and phase2_engine == "sharded":
        # one global sharded-jit program (the production engine lowering)
        with set_mesh(mesh):
            lowered, _ = _ensemble_sharded_lower(cfg, shape, mesh, n_workers)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
    else:
        if phase2:
            fn, args, block_mesh = _ensemble_jit(model, cfg, shape, mesh,
                                                 n_workers)
            ctx_mesh = block_mesh
        else:
            fn, args = _jit_for_shape(model, cfg, shape, mesh,
                                      precision=precision,
                                      grad_accum_steps=grad_accum_steps)
            ctx_mesh = mesh
        with set_mesh(ctx_mesh):
            lowered = fn.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()

    # Roofline terms: the production (scanned) compile above proves the
    # sharding + memory story; exact per-device flops/bytes/collectives come
    # from the unrolled 1-unit/2-unit extrapolation (scan bodies are counted
    # once by cost_analysis regardless of trip count).
    t3 = time.perf_counter()
    if phase2:
        extra = _terms_from_compiled(compiled)  # structure check only
    else:
        extra = roofline_extrapolated(arch, shape, mesh, cfg,
                                      precision=precision,
                                      grad_accum_steps=grad_accum_steps)
    t4 = time.perf_counter()

    flops_dev = extra["flops"]
    bytes_dev = extra["bytes"]
    coll_dev = extra["coll"]
    coll = {k: float(v) for k, v in extra["coll_by_kind"].items()}
    mf = model_flops(cfg, SHAPES[shape_name])

    rec.update({
        "n_devices": int(n_dev),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "roofline_probe_s": round(t4 - t3, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll,
        "model_flops_total": mf,
        "useful_compute_ratio": (mf / (flops_dev * n_dev)
                                 if flops_dev else None),
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    })
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    if ma is not None:
        rec["memory_analysis"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    if phase2:
        per_worker = n_dev // n_workers
        n_groups = assert_no_cross_worker_collectives(hlo, n_workers,
                                                      per_worker)
        rec["phase2_collective_groups_checked"] = n_groups
        rec["phase2_no_cross_worker_collectives"] = True
        rec["phase2_deployment"] = (
            f"one sharded-jit program, {n_workers} worker blocks x "
            f"{per_worker} chips"
            if phase2_engine == "sharded" else
            f"{n_workers} independent programs x {per_worker} chips")
    return rec


def _ensemble_sharded_lower(cfg: ModelConfig, shape: ShapeConfig, mesh,
                            n_workers: int, n_steps: int = 2):
    """Phase-2 lowered the way the PRODUCTION engine runs it: ONE
    sharded-jit program over the whole worker mesh —
    ``EpochRunner(engine="sharded")``, i.e. ``vmap(scan(step),
    spmd_axis_name="worker")`` with the carried TrainState pinned to
    ``ensemble_shardings``. ``spmd_axis_name`` stamps the worker axis onto
    every vmapped intermediate inside the partitioner, which keeps DENSE
    transformer chunks collective-free (internlm2-1.8b train_4k at 256
    devices: zero collective groups in the compiled HLO — the weekly CI
    audit). It does NOT close the MoE scatter/top_k escape the bare-vmap
    form had (see ``_ensemble_jit``'s history note): granite-moe under
    this lowering still emits a cross-worker all-reduce, which the
    downstream audit catches and fails loudly. MoE archs therefore audit
    (and deploy) via the per-worker-block ``programs`` engine.

    Returns ``(lowered, n_steps)`` — a lowered (not compiled) chunk of
    ``n_steps`` scanned train steps over a tiny zero-token dataset (the
    audit is about program STRUCTURE; batch content never matters)."""
    from repro.core.adapters import LMAdapter
    from repro.data.pipeline import Loader
    from repro.train.loop import EpochRunner, TrainState
    from repro.train.precision import default_scale_state, stack_scale_state

    W = n_workers
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    step_fn = adapter.make_train_step(
        schedule_fn(ScheduleConfig(kind="const")))
    # per-worker batch = global batch / W (paper: B2 = B1/W); dataset is
    # n_steps batches so the loader's epoch covers the lowered chunk
    B = max(shape.global_batch // W, 1)
    import numpy as np
    arrays = {"tokens": np.zeros((B * n_steps, shape.seq_len), np.int32),
              "labels": np.zeros((B * n_steps, shape.seq_len), np.int32)}
    loader = Loader(arrays, B)
    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True,
                         mesh=mesh, engine="sharded")

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((W,) + s.shape, s.dtype), tree)

    bundle = jax.eval_shape(adapter.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(adapter.init_opt, bundle)
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    scale = jax.eval_shape(
        lambda: stack_scale_state(default_scale_state(), W))
    i32 = jnp.int32
    state = TrainState(
        bundle=stack(bundle), opt_state=stack(opt),
        step=jax.ShapeDtypeStruct((W,), i32),
        acc_ema=jax.ShapeDtypeStruct((W,), jnp.float32),
        phase=jax.ShapeDtypeStruct((W,), i32),
        rng=jax.ShapeDtypeStruct((W,) + key.shape, key.dtype),
        scale=scale)
    worker = jax.ShapeDtypeStruct((W,), i32)
    return runner.lower_chunk(state, worker, n_steps), n_steps


def _ensemble_jit(model: Model, cfg: ModelConfig, shape: ShapeConfig, mesh,
                  n_workers: int):
    """Phase-2 SWAP step, compiled the way it DEPLOYS: one independent
    program per worker block, exactly like the paper's Horovod phase 2 (W
    separate single-GPU processes). Cross-worker collectives are impossible
    by construction — each program only spans its own block's devices; the
    assert downstream re-verifies that every HLO replica group stays within
    one block.

    (We first tried a single global program — vmap with a sharded worker
    axis, then partial-manual shard_map. The vmap form lets the SPMD
    partitioner escape across the worker axis on scatter/top_k ops (MoE
    router probs, kv=1 attention all-gathers, 16-160MB each); the shard_map
    form CHECK-crashes XLA's spmd_partitioner on the same archs. Both
    observations are recorded in EXPERIMENTS.md §Dry-run. Independent
    programs are also operationally truer: phase-2 workers shouldn't share
    a lockstep dispatch loop.)"""
    opt_cfg = OptimizerConfig(kind="sgd")
    opt_init, train_step = make_lm_train_step(
        model, opt_cfg, schedule_fn(ScheduleConfig(kind="const")))
    specs = input_specs(cfg, shape)
    W = n_workers

    # worker block mesh: the first (data/W, model) block of the global mesh
    n_dev = mesh.devices.size
    block_size = n_dev // W
    model_par = mesh.shape["model"]
    block_devices = mesh.devices.reshape(-1)[:block_size].reshape(
        block_size // model_par, model_par)
    block_mesh = jax.sharding.Mesh(block_devices, ("data", "model"))

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt_init, params_shape)
    # per-worker batch = shape's global batch / W (paper: B2 = B1/W)
    bs = {k: jax.ShapeDtypeStruct((v.shape[0] // W,) + v.shape[1:], v.dtype)
          for k, v in specs.items()}

    p_sh = param_shardings(block_mesh, params_shape)
    o_sh = param_shardings(block_mesh, opt_shape)
    b_sh = batch_shardings(block_mesh, bs)
    repl = NamedSharding(block_mesh, P())

    fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh, repl),
                 out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    return fn, (params_shape, opt_shape, bs,
                jax.ShapeDtypeStruct((), jnp.int32)), block_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=registry.ASSIGNED_ARCHS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--phase2", action="store_true")
    ap.add_argument("--phase2-engine", default="programs",
                    choices=["programs", "sharded"],
                    help="phase-2 lowering to audit: per-worker-block "
                         "independent programs (deployment-shaped, safe "
                         "for every arch) or the production sharded-jit "
                         "engine (one global program, "
                         "vmap+spmd_axis_name with pinned shardings)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--precision", default="float32",
                    choices=["float32", "bfloat16"],
                    help="train-shape numerics: bf16 compute + f32 master "
                         "weights (f16's dynamic scaling is stateful — "
                         "engine-only, not AOT-lowerable here)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="train-shape microbatch accumulation factor")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    results = {}
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)

    for arch in args.arch:
        for shape in args.shape:
            for mesh_kind in meshes:
                key = f"{arch}|{shape}|{mesh_kind}" + \
                    ("|phase2" if args.phase2 else "") + \
                    ("|sharded" if args.phase2
                     and args.phase2_engine == "sharded" else "") + \
                    (f"|{args.precision}" if args.precision != "float32"
                     else "") + \
                    (f"|accum{args.grad_accum}" if args.grad_accum > 1
                     else "")
                if args.skip_existing and results.get(key, {}).get("status") == "ok":
                    print(f"[skip] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_one(arch, shape, mesh_kind, phase2=args.phase2,
                                  n_workers=args.workers,
                                  precision=args.precision,
                                  grad_accum_steps=args.grad_accum,
                                  phase2_engine=args.phase2_engine)
                except (ValueError, TypeError, KeyError,
                        NotImplementedError, RuntimeError) as e:
                    # the failure modes a sweep tolerates and records:
                    # config/shape validation (ValueError/TypeError/
                    # KeyError), arch paths a lowering doesn't implement
                    # (NotImplementedError), and XLA lowering/compile
                    # failures (XlaRuntimeError subclasses RuntimeError).
                    # Anything else — KeyboardInterrupt, MemoryError, a
                    # genuine bug — aborts the sweep instead of being
                    # silently filed as one more per-config error record.
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"bottleneck={rec['bottleneck']}")
                elif status == "error":
                    extra = f" {rec['error']}"
                print(f"[done] {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    print(f"summary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
