"""Mixed precision + gradient accumulation for the training stack.

SWAP's phase 1 is defined by very large mini-batches; this module supplies
the two levers that make that regime run "as fast as the hardware allows":

  * ``PrecisionPolicy`` — a frozen, hashable description of the numerics of
    one training phase: master-parameter dtype, forward/backward compute
    dtype, the dtype gradients are cast to before the data-axis reduction,
    and (for float16) dynamic loss scaling with inf/nan step skipping.
    Master weights always stay in ``param_dtype`` (float32 by default);
    reduced precision applies to the compute path and the gradient
    reduction only, so the optimizer update — and everything SWAP averages
    in phase 3 — is full precision.
  * ``LossScaleState`` — the tiny pytree of loss-scaling dynamics (current
    scale, growth counter, cumulative skipped-step counter) that the phase
    engine threads through ``TrainState`` and checkpoints alongside the
    model (see ``repro.train.loop`` / ``repro.checkpoint.state``).
  * ``make_precision_train_step`` — wraps a loss function and an optimizer
    update into the engine's step signature

        (bundle, opt_state, batch, step, scale_state)
            -> (bundle, opt_state, scale_state, metrics)

    handling compute-dtype casting, loss scaling, microbatch gradient
    accumulation (an inner ``lax.scan`` over ``grad_accum_steps`` slices of
    the global batch, so phase-1 batches larger than device memory run as
    accumulated microbatches with identical effective batch size), the
    skip-on-overflow update, and the master-weight optimizer step.

Equivalences the tests pin down (``tests/test_precision.py``):
``grad_accum_steps=k`` over microbatches of ``B/k`` matches the fused
batch-``B`` step to FMA tolerance for stateless models (the LM), and the
pure-float32 policy traces the exact pre-precision step graph (no extra
casts or selects), keeping the engine's bitwise python-loop equivalence
intact. Stateful models are NOT fused-equivalent under accumulation:
BatchNorm statistics are computed per microbatch (k sequential
running-stat updates instead of one batch-B statistic) and the CNN's
augmentation seed is per-global-batch — see docs/training.md
§Precision & accumulation.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class LossScaleState(NamedTuple):
    """Loss-scaling dynamics, carried in ``TrainState.scale``.

    Plain-f32 policies carry the trivial state (scale 1, counters 0) so the
    TrainState structure — and therefore checkpoints — is uniform across
    precision configurations.
    """

    scale: Any         # float32 scalar — current loss scale
    growth_count: Any  # int32 — finite steps since the last scale change
    skipped: Any       # int32 — cumulative inf/nan-skipped steps


@dataclass(frozen=True)
class PrecisionPolicy:
    """Numerics of one training phase. Frozen + hashable (jit-static)."""

    name: str = "float32"
    param_dtype: str = "float32"    # master weights (optimizer + averaging)
    compute_dtype: str = "float32"  # forward/backward math
    grad_dtype: str = "float32"     # gradient dtype for the data-axis psum
    loss_scale: float = 1.0         # initial (or fixed) loss scale
    dynamic: bool = False           # dynamic scaling + inf/nan step skip
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 200      # finite steps between scale growths

    @property
    def scaled(self) -> bool:
        return self.dynamic or self.loss_scale != 1.0

    @property
    def casts_compute(self) -> bool:
        return self.compute_dtype != self.param_dtype

    def cast_for_compute(self, tree):
        """Cast floating leaves to the compute dtype (no-op for f32/f32)."""
        if not self.casts_compute:
            return tree
        dt = jnp.dtype(self.compute_dtype)
        return jax.tree_util.tree_map(
            lambda a: a.astype(dt)
            if jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact) else a, tree)

    def init_scale_state(self) -> LossScaleState:
        return LossScaleState(
            scale=jnp.asarray(self.loss_scale, jnp.float32),
            growth_count=jnp.zeros((), jnp.int32),
            skipped=jnp.zeros((), jnp.int32))

    def update_scale(self, st: LossScaleState, finite) -> LossScaleState:
        """Post-step scaling dynamics: back off on overflow, grow after
        ``growth_interval`` consecutive finite steps."""
        grown = st.growth_count + 1 >= self.growth_interval
        scale = jnp.where(
            finite,
            jnp.where(grown, st.scale * self.growth_factor, st.scale),
            st.scale * self.backoff_factor)
        count = jnp.where(finite & ~grown, st.growth_count + 1, 0)
        return LossScaleState(
            scale=scale.astype(jnp.float32),
            growth_count=count.astype(jnp.int32),
            skipped=st.skipped + (1 - finite.astype(jnp.int32)))


F32 = PrecisionPolicy()
BF16 = PrecisionPolicy(name="bfloat16", compute_dtype="bfloat16")
# float16's narrow exponent needs loss scaling; start high, dynamics adapt
F16 = PrecisionPolicy(name="float16", compute_dtype="float16",
                      loss_scale=2.0 ** 15, dynamic=True)

_PRESETS = {
    "": F32, "f32": F32, "float32": F32, "fp32": F32,
    "bf16": BF16, "bfloat16": BF16,
    "f16": F16, "float16": F16, "fp16": F16,
}


def default_scale_state() -> LossScaleState:
    """The trivial (f32) loss-scale state — what plain callers thread."""
    return F32.init_scale_state()


def stack_scale_state(st: LossScaleState, n: int) -> LossScaleState:
    """Broadcast a scale state to a leading worker axis (phase-2 ensembles:
    every worker starts from the same scale, then evolves independently)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), st)


def resolve_policy(name: str, opt_cfg=None) -> PrecisionPolicy:
    """Preset name -> policy, folding in the deprecated
    ``OptimizerConfig.grad_dtype`` alias: a non-f32 grad dtype on the
    optimizer config still parses and lands on the policy (unless the
    preset already sets one), but the cast now happens inside the precision
    step — after unscaling, before the data-axis reduction — instead of as
    a loose post-``value_and_grad`` cast."""
    policy = _PRESETS.get((name or "").lower())
    if policy is None:
        raise ValueError(
            f"unknown precision preset {name!r}; "
            f"expected one of {sorted(k for k in _PRESETS if k)}")
    if (opt_cfg is not None and opt_cfg.grad_dtype != "float32"
            and policy.grad_dtype == "float32"):
        warnings.warn(
            "OptimizerConfig.grad_dtype is deprecated: set "
            "PhaseConfig.precision / PrecisionPolicy.grad_dtype instead "
            "(the value still applies, now inside the precision step)",
            DeprecationWarning, stacklevel=2)
        policy = dataclasses.replace(policy, grad_dtype=opt_cfg.grad_dtype)
    return policy


def split_microbatches(batch, k: int):
    """Reshape every batch leaf ``(B, ...) -> (k, B/k, ...)``; scalar
    leaves (e.g. the per-batch ``aug_seed``) broadcast across microbatches."""
    def split(v):
        v = jnp.asarray(v)
        if v.ndim == 0:
            return jnp.broadcast_to(v, (k,))
        if v.shape[0] % k:
            raise ValueError(
                f"batch dim {v.shape[0]} not divisible by "
                f"grad_accum_steps={k}")
        return v.reshape((k, v.shape[0] // k) + v.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def all_finite(tree):
    """Scalar bool: every inexact leaf of ``tree`` is finite."""
    fin = jnp.asarray(True)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            fin = fin & jnp.all(jnp.isfinite(leaf))
    return fin


def make_precision_train_step(loss_with_aux: Callable, opt_update: Callable,
                              schedule_fn: Callable,
                              policy: Optional[PrecisionPolicy] = None,
                              grad_accum_steps: int = 1,
                              cast_inputs: bool = True) -> Callable:
    """The engine-facing train step with the full precision pipeline.

    ``loss_with_aux(params, model_state, batch) -> (loss, (metrics,
    new_model_state))`` — the CNN adapter's loss already has this shape;
    stateless losses pass ``{}`` through. ``cast_inputs=False`` skips the
    pre-cast of params/batch for models that already cast per-op from their
    own compute-dtype config (the LM's ``mdot``); the scaling/accumulation/
    skip machinery is identical either way.

    Skip semantics (``policy.dynamic``): when any unscaled gradient leaf is
    non-finite, parameters, optimizer state, and model state keep their
    previous values, the scale backs off, and ``scale_state.skipped``
    increments; ``metrics["skipped"]`` flags the step so the phase engine
    can freeze its accuracy EMA for it.
    """
    policy = policy or F32
    k = int(grad_accum_steps)
    if k < 1:
        raise ValueError(f"grad_accum_steps must be >= 1, got {k}")
    grad_dtype = jnp.dtype(policy.grad_dtype)
    tree_map = jax.tree_util.tree_map

    def train_step(bundle, opt_state, batch, step, scale_state):
        params, mstate = bundle["params"], bundle["state"]
        scale = scale_state.scale

        def scaled_loss(p, st, mb):
            if cast_inputs:
                p, mb = policy.cast_for_compute((p, mb))
            loss, (metrics, new_st) = loss_with_aux(p, st, mb)
            # model state (BN running stats) stays in its master dtypes so
            # the scan carry — and checkpoints — are dtype-stable
            new_st = tree_map(lambda n, o: n.astype(o.dtype), new_st, st)
            if policy.scaled:
                loss = loss * scale.astype(loss.dtype)
            return loss, (metrics, new_st)

        vg = jax.value_and_grad(scaled_loss, has_aux=True)

        if k == 1:
            (_, (metrics, new_mstate)), grads = vg(params, mstate, batch)
        else:
            # zero-seeded carry so ALL k microbatches run through the one
            # scan body — unrolling microbatch 0 to seed the carry would
            # compile a second full fwd+bwd copy into the step
            micro = split_microbatches(batch, k)
            (_, (m_sh, _)), g_sh = jax.eval_shape(
                vg, params, mstate, tree_map(lambda v: v[0], micro))
            zeros = lambda t: tree_map(                       # noqa: E731
                lambda s: jnp.zeros(s.shape, s.dtype), t)

            def body(carry, mb):
                g_acc, m_acc, st = carry
                (_, (m_i, st_i)), g_i = vg(params, st, mb)
                return (tree_map(jnp.add, g_acc, g_i),
                        tree_map(jnp.add, m_acc, m_i), st_i), None

            (grads, msum, new_mstate), _ = jax.lax.scan(
                body, (zeros(g_sh), zeros(m_sh), mstate), micro)
            metrics = tree_map(lambda m: m / k, msum)

        # unscale (and average over microbatches) in one multiply, then cast
        # to the reduction dtype: the data-axis psum of the backward pass
        # happens on these leaves
        if policy.scaled or k > 1:
            inv = (1.0 / k) / scale if policy.scaled else jnp.float32(1.0 / k)
            grads = tree_map(lambda g: (g * inv.astype(g.dtype)), grads)
        if grad_dtype != jnp.float32:
            grads = tree_map(lambda g: g.astype(grad_dtype), grads)

        lr = schedule_fn(step)
        new_params, new_opt = opt_update(grads, opt_state, params, lr)
        if policy.dynamic:
            finite = all_finite(grads)
            keep = lambda n, o: jnp.where(finite, n, o)  # noqa: E731
            new_params = tree_map(keep, new_params, params)
            new_opt = tree_map(keep, new_opt, opt_state)
            new_mstate = tree_map(keep, new_mstate, mstate)
            new_scale = policy.update_scale(scale_state, finite)
            metrics = dict(metrics,
                           skipped=1.0 - finite.astype(jnp.float32),
                           loss_scale=scale)
        else:
            new_scale = scale_state
        return ({"params": new_params, "state": new_mstate}, new_opt,
                new_scale, dict(metrics, lr=lr))

    return train_step
