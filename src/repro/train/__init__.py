from repro.train.loop import (
    EpochRunner, PhaseResult, TrainState, init_train_state,
    python_loop_reference, run_phase, stack_train_state,
)
from repro.train.precision import (
    BF16, F16, F32, LossScaleState, PrecisionPolicy, default_scale_state,
    make_precision_train_step, resolve_policy, split_microbatches,
    stack_scale_state,
)
from repro.train.steps import (
    lm_loss_and_metrics, make_decode_fn, make_lm_eval_fn, make_lm_train_step,
    make_prefill_fn,
)
