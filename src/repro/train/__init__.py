from repro.train.steps import (
    lm_loss_and_metrics, make_decode_fn, make_lm_eval_fn, make_lm_train_step,
    make_prefill_fn,
)
