"""Compiled phase engine: scan-based epoch runner over device-resident data.

The SWAP controller used to dispatch one jitted step per Python iteration
and rebuild W worker batches on the host every step — the host loop, not
the hardware, set the step rate. This module replaces that with an
epoch-granular runner:

  * ``TrainState`` — the single pytree that flows through every phase:
    (bundle, opt_state, step, acc_ema, phase tag, rng, loss-scale state).
    Phase 2 carries the same structure with a leading W worker axis on
    every leaf. Train steps have the precision-pipeline signature
    ``(bundle, opt_state, batch, step, scale) -> (bundle, opt_state,
    scale, metrics)`` (see ``repro.train.precision``); plain-f32 phases
    thread the trivial scale state so the engine — and checkpoints — are
    uniform across precision configurations.
  * ``EpochRunner`` — compiles ``lax.scan(train_step)`` over an epoch-sized
    chunk inside ONE jit (vmapped over the worker axis for phase 2). Each
    scanned step gathers its batch in-trace via ``Loader.batch_in_trace``,
    so no per-step host work or host->device transfer remains. On a worker
    mesh the ensemble runner lowers as a SHARDED-JIT program
    (``engine="sharded"``): ``vmap(..., spmd_axis_name="worker")`` with the
    in/out state shardings pinned to ``dist.sharding.ensemble_shardings``,
    so the partitioner carries the worker axis on every vmapped
    intermediate and the compiled program contains no cross-worker
    collectives (checked by ``assert_no_cross_worker_collectives``). The
    plain-vmap form stays as the bitwise equivalence oracle.
  * ``run_phase`` — the thin host driver: one compiled call per epoch,
    early-exit on the accuracy EMA at *epoch boundaries* (the streaming
    equivalent of the paper's per-epoch train-accuracy check), metric-log
    extraction, periodic checkpointing, and an ``on_chunk`` hook (curve
    collection / eval) whose wall time is accounted separately from train
    time.
  * ``python_loop_reference`` — the replaced per-step host loop, kept as
    the equivalence oracle for tests and the baseline for
    ``benchmarks/bench_train_loop.py``.

Chunk lengths are static (steps_per_epoch, plus one shorter final chunk),
so a phase compiles at most two programs per runner.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Loader
from repro.train.precision import (
    LossScaleState, default_scale_state, stack_scale_state,
)

# phase tags carried inside TrainState (checkpointable, trace-friendly)
PHASE_TAGS = {"sgd": 0, "phase1": 1, "phase2": 2}


class TrainState(NamedTuple):
    """Everything a phase needs to continue training from an exact point.

    A registered pytree (NamedTuple), so it vmaps over a leading worker
    axis, flows through ``lax.scan`` as the carry, and round-trips through
    ``repro.checkpoint`` byte-exactly.
    """

    bundle: Any        # {"params": ..., "state": ...}
    opt_state: Any
    step: Any          # int32 scalar (per-worker vector in phase 2)
    acc_ema: Any       # float32 scalar — streaming train-accuracy EMA
    phase: Any         # int32 PHASE_TAGS value
    rng: Any           # PRNGKey (reserved for stochastic steps)
    scale: Any         # LossScaleState (trivial for plain-f32 policies)


def init_train_state(bundle, opt_state, *, step: int = 0,
                     acc_ema: float = 0.0, phase: str = "phase1",
                     seed: int = 0,
                     scale: Optional[LossScaleState] = None) -> TrainState:
    return TrainState(
        bundle=bundle, opt_state=opt_state,
        step=jnp.asarray(step, jnp.int32),
        acc_ema=jnp.asarray(acc_ema, jnp.float32),
        phase=jnp.asarray(PHASE_TAGS.get(phase, 0), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        scale=scale if scale is not None else default_scale_state())


def stack_train_state(stacked_bundle, stacked_opt_state, n_workers: int,
                      seed: int = 0,
                      scale: Optional[LossScaleState] = None) -> TrainState:
    """Assemble the phase-2 start state from an already-stacked bundle
    (every worker begins from the common phase-1 model) and freshly
    initialized per-worker optimizer state, both with a leading W axis."""
    return TrainState(
        bundle=stacked_bundle, opt_state=stacked_opt_state,
        step=jnp.zeros((n_workers,), jnp.int32),
        acc_ema=jnp.zeros((n_workers,), jnp.float32),
        phase=jnp.full((n_workers,), PHASE_TAGS["phase2"], jnp.int32),
        rng=jax.random.split(jax.random.PRNGKey(seed), n_workers),
        scale=stack_scale_state(
            scale if scale is not None else default_scale_state(), n_workers))


class EpochRunner:
    """jit(lax.scan(train_step)) over epoch-sized chunks, with the batch
    gathered in-trace.

    ``ensemble=True`` vmaps the whole scanned epoch over the leading worker
    axis of the state (SWAP phase 2): one compiled program advances all W
    workers a full epoch, and — with the state placed by
    ``dist.sharding.ensemble_shardings`` on a worker mesh — lowers to W
    independent per-worker sub-programs with no cross-worker collectives.

    ``engine`` picks the ensemble lowering (``repro.dist.DistConfig``
    resolves it; non-ensemble runners ignore it):

      * ``"vmap"`` (default) — plain ``jax.vmap``; single-device oracle.
      * ``"sharded"`` — ``jax.vmap(..., spmd_axis_name="worker")`` jitted
        with ``in_shardings``/``out_shardings`` pinned to
        ``ensemble_shardings(mesh, ...)``. ``spmd_axis_name`` stamps the
        worker axis onto every vmapped intermediate inside the partitioner,
        so per-worker content cannot be re-gathered across workers — the
        lowering the no-cross-worker-collective audit runs against, and the
        form a real worker mesh (worker axis across hosts) executes.
        Requires ``mesh`` with a ``worker`` axis. Bitwise-identical to the
        ``"vmap"`` engine on the same mesh (asserted in
        tests/test_sharded_engine.py).

        (``shard_map`` with auto-managed inner axes was tried first and
        CHECK-crashes XLA's spmd_partitioner on JAX 0.4.37 — see
        ``launch.dryrun._ensemble_jit``'s history note.)

    Compiled programs are cached per chunk length; the input state is
    donated (``donate=False`` — DistConfig.donate_state — keeps the
    caller's buffers alive instead), so long runs do not accumulate
    buffers.

    ``unroll=True`` emits the chunk as straight-line code instead of an XLA
    ``while`` loop (capped at ``_UNROLL_CAP`` steps to bound compile time).
    XLA:CPU executes convolutions inside while-loop bodies on a slow
    non-vectorized path (~8x at smoke scale, independent of thread count),
    so conv models on CPU hosts should unroll; LM/transformer chunks are
    fastest in while form, and on TPU the while form is always right
    (compile-bounded, Pallas-compatible). The choice only affects scheduling
    — per-step math is identical either way.
    """

    _UNROLL_CAP = 32

    def __init__(self, step_fn: Callable, loader: Loader, ema_beta: float,
                 ensemble: bool = False, unroll: bool = False,
                 mesh=None, engine: str = "vmap", donate: bool = True):
        if engine not in ("vmap", "sharded"):
            raise ValueError(f"engine must be 'vmap' or 'sharded', "
                             f"got {engine!r}")
        if engine == "sharded":
            if not ensemble:
                raise ValueError("engine='sharded' is the ensemble lowering "
                                 "(worker axis); use ensemble=True")
            if mesh is None or "worker" not in mesh.axis_names:
                raise ValueError("engine='sharded' needs a mesh with a "
                                 "'worker' axis (see DistConfig.make_mesh / "
                                 "launch.mesh.make_worker_mesh)")
        self.step_fn = step_fn
        self.loader = loader
        self.ema_beta = ema_beta
        self.ensemble = ensemble
        self.unroll = unroll
        self.mesh = mesh
        self.engine = engine
        self.donate = donate
        self._compiled: Dict[int, Callable] = {}

    def _chunk_fn(self, n_steps: int, state=None, worker=None) -> Callable:
        fn = self._compiled.get(n_steps)
        if fn is not None:
            return fn
        step_fn, loader, beta = self.step_fn, self.loader, self.ema_beta

        def run_chunk(state: TrainState, worker):
            def body(st, _):
                batch = loader.batch_in_trace(st.step, worker)
                bundle, opt, scale, metrics = step_fn(
                    st.bundle, st.opt_state, batch, st.step, st.scale)
                ema = (beta * st.acc_ema
                       + (1.0 - beta) * metrics["accuracy"]
                       .astype(jnp.float32))
                if "skipped" in metrics:
                    # dynamic-loss-scale policies flag overflow steps; the
                    # stopping EMA must not absorb their (unapplied) batch
                    ema = jnp.where(metrics["skipped"] > 0, st.acc_ema, ema)
                st = TrainState(bundle, opt, st.step + 1, ema,
                                st.phase, st.rng, scale)
                return st, dict(metrics, ema=ema)

            return jax.lax.scan(body, state, xs=None, length=n_steps,
                                unroll=(self.unroll
                                        and n_steps <= self._UNROLL_CAP))

        donate = (0,) if self.donate else ()
        if self.ensemble and self.engine == "sharded":
            # ONE sharded-jit program: spmd_axis_name pins the worker axis
            # of every vmapped intermediate in the partitioner, and the
            # explicit in/out shardings pin the carried state, so nothing
            # can be re-gathered across worker blocks. Shardings are
            # derived from the example state/worker (ShapeDtypeStructs
            # suffice — only shapes matter), whose structure is fixed for
            # the runner's lifetime.
            if state is None or worker is None:
                raise ValueError("sharded engine needs the example state/"
                                 "worker to derive shardings")
            from repro.dist.sharding import ensemble_shardings
            st_sh = ensemble_shardings(self.mesh, state)
            wk_sh = ensemble_shardings(self.mesh, worker)
            fn = jax.jit(jax.vmap(run_chunk, spmd_axis_name="worker"),
                         in_shardings=(st_sh, wk_sh),
                         out_shardings=(st_sh, None),
                         donate_argnums=donate)
        else:
            if self.ensemble:
                run_chunk = jax.vmap(run_chunk)
            fn = jax.jit(run_chunk, donate_argnums=donate)
        self._compiled[n_steps] = fn
        return fn

    def run_chunk(self, state: TrainState, worker, n_steps: int):
        """Advance ``n_steps`` inside one compiled call. Returns
        (new_state, metrics) with every metric stacked over the step axis
        (``(n_steps,)`` leaves; ``(W, n_steps)`` for ensembles)."""
        return self._chunk_fn(n_steps, state, worker)(state, worker)

    def lower_chunk(self, state, worker, n_steps: int):
        """AOT-lower one chunk without executing it (``state``/``worker``
        may be ShapeDtypeStructs). The dry-run collective audit lowers the
        sharded phase-2 engine this way on a 256-fake-device mesh."""
        return self._chunk_fn(n_steps, state, worker).lower(state, worker)


class PhaseResult(NamedTuple):
    state: TrainState
    steps: int          # steps executed by THIS driver invocation
    train_time: float   # wall time inside compiled train chunks only
    hook_time: float    # wall time in on_chunk / checkpoint / logging


def _ema_value(state: TrainState) -> float:
    ema = np.asarray(state.acc_ema)
    return float(ema if ema.ndim == 0 else ema.min())


def as_hooks(on_chunk) -> tuple:
    """Normalize ``run_phase``'s ``on_chunk`` argument — None, a single
    callable, or a sequence of callables — into a tuple. The epoch-boundary
    hook surface: every hook is called as ``hook(state, steps_done)`` after
    each compiled chunk, in order (curve eval, live weight publishing via
    ``repro.serve.publish.WeightPublisher.on_epoch``, ...)."""
    if on_chunk is None:
        return ()
    if callable(on_chunk):
        return (on_chunk,)
    return tuple(on_chunk)


def _append_log(log: List[dict], metrics: Dict, first_step: int) -> None:
    host = {k: np.asarray(v) for k, v in metrics.items()
            if k in ("accuracy", "ema", "loss", "lr")}
    n = host["accuracy"].shape[-1]
    for i in range(n):
        log.append({"step": first_step + i,
                    "accuracy": float(host["accuracy"][..., i]),
                    "ema": float(host["ema"][..., i]),
                    "loss": float(host["loss"][..., i]),
                    "lr": float(host["lr"][..., i])})


def run_phase(runner: EpochRunner, state: TrainState, worker, *,
              max_steps: int, stop_accuracy: Optional[float] = None,
              chunk_steps: Optional[int] = None, log: Optional[list] = None,
              checkpointer=None, tag: str = "phase1",
              checkpoint_meta: Optional[Callable] = None,
              on_chunk: Optional[Callable] = None) -> PhaseResult:
    """Drive a phase to completion: epoch-sized compiled chunks with
    early-exit on the accuracy EMA at epoch boundaries.

    ``max_steps`` counts from the CURRENT ``state.step`` (so a resumed state
    runs only the remainder). ``on_chunk`` — one callable or a sequence of
    them, each ``hook(state, steps_done)`` — and checkpointing run between
    chunks; their time is returned separately in ``hook_time`` so
    eval/publishing never pollutes the train-rate measurement.
    ``checkpoint_meta(train_time_so_far) -> dict`` attaches caller metadata
    (e.g. cumulative phase wall/train time, so a later resume can report
    totals instead of remainder-only figures) to each snapshot.

    Mid-chunk entry realigns to epoch boundaries: when ``state.step`` is
    not a chunk multiple (a phase resumed from a snapshot cut mid-epoch,
    e.g. by a max_steps cap), the FIRST chunk is truncated to the next
    boundary. Without this, every post-resume chunk ended mid-epoch, so
    the stopping check consulted an EMA whose latest fold predates the
    true epoch boundary — the documented epoch-boundary semantics
    (docs/training.md) silently shifted by the resume offset.
    """
    if log is not None and runner.ensemble:
        raise ValueError(
            "per-step logs are single-model only: ensemble metrics carry a "
            "leading worker axis — consume them via on_chunk instead")
    chunk = chunk_steps or runner.loader.steps_per_epoch
    hooks = as_hooks(on_chunk)
    done, train_time, hook_time = 0, 0.0, 0.0
    # entry check, not just post-chunk: a restored state that already meets
    # the threshold (killed between its last snapshot and the phase-final
    # save) must not train an extra epoch — resume stays bit-exact
    if stop_accuracy is not None and _ema_value(state) >= stop_accuracy:
        return PhaseResult(state, 0, 0.0, 0.0)
    offset = int(np.asarray(state.step).reshape(-1)[0]) % chunk
    first = chunk - offset if offset else chunk
    while done < max_steps:
        n = min(first if done == 0 else chunk, max_steps - done)
        t0 = time.perf_counter()
        state, metrics = runner.run_chunk(state, worker, n)
        jax.block_until_ready(state.bundle)
        train_time += time.perf_counter() - t0
        done += n

        t1 = time.perf_counter()
        if log is not None:
            start = int(np.asarray(state.step).reshape(-1)[0]) - n
            _append_log(log, metrics, start)
        for hook in hooks:
            hook(state, done)
        if checkpointer is not None:
            checkpointer.maybe_save(
                tag, state,
                checkpoint_meta(train_time) if checkpoint_meta else None)
        hook_time += time.perf_counter() - t1

        if stop_accuracy is not None and _ema_value(state) >= stop_accuracy:
            break
    return PhaseResult(state, done, train_time, hook_time)


def stack_host_batches(loader: Loader, step: int, n_workers: int):
    """The replaced phase-2 host path: build every worker's batch on the
    host and stack along a leading W axis. Baseline/oracle only — the
    engine gathers batches in-trace instead (``Loader.batch_in_trace``)."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[loader.batch(step, worker=w) for w in range(n_workers)])


def python_loop_reference(step_fn: Callable, loader: Loader,
                          state: TrainState, worker: int = 0, *,
                          n_steps: int, ema_beta: float):
    """The per-step host-driven loop the scan engine replaced: one jitted
    step dispatch per Python iteration, batch built on the host each step.

    Kept as the equivalence oracle (tests assert the scan engine reproduces
    it exactly) and as the baseline side of
    ``benchmarks/bench_train_loop.py``. Returns (state, per-step log dicts).
    """
    fn = jax.jit(step_fn, donate_argnums=(0, 1))
    bundle, opt, scale = state.bundle, state.opt_state, state.scale
    start = int(np.asarray(state.step))
    ema = jnp.asarray(state.acc_ema)
    logs = []
    for s in range(start, start + n_steps):
        batch = loader.batch(s, worker=worker)
        bundle, opt, scale, metrics = fn(bundle, opt, batch, s, scale)
        new_ema = (ema_beta * ema
                   + (1.0 - ema_beta) * metrics["accuracy"]
                   .astype(jnp.float32))
        if "skipped" in metrics:
            new_ema = jnp.where(metrics["skipped"] > 0, ema, new_ema)
        ema = new_ema
        logs.append({"step": s, "accuracy": float(metrics["accuracy"]),
                     "ema": float(ema), "loss": float(metrics["loss"]),
                     "lr": float(metrics["lr"])})
    jax.block_until_ready(bundle)
    return state._replace(
        bundle=bundle, opt_state=opt, scale=scale,
        step=jnp.asarray(start + n_steps, jnp.int32),
        acc_ema=ema.astype(jnp.float32)), logs
