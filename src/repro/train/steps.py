"""Step factories: training, eval, prefill, decode.

All steps are pure functions (params, ...) -> (params, ...) suitable for
jax.jit with explicit in/out shardings; the SWAP controller and the dry-run
both consume them.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.dist.sharding import logical_constraint
from repro.models.model import Model
from repro.optim.api import init_optimizer


def lm_loss_and_metrics(model: Model, params, batch: Dict):
    """Cross-entropy next-token loss + router aux; metrics incl. accuracy
    (the paper's phase-1 stopping criterion is TRAIN accuracy)."""
    logits, aux = model.apply(
        params, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        frames=batch.get("frames"))
    labels = batch["labels"]
    # CE without take_along_axis / full f32 logits: gathers over a
    # vocab-sharded logits tensor force GSPMD all-gathers (§Perf iter 1);
    # the masked reduction keeps every op vocab-shardable.
    logits_f = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits_f, axis=-1, keepdims=True))
    shifted = logits_f - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    V = logits.shape[-1]
    label_mask = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1)
                  == labels[..., None])
    l_y = jnp.sum(jnp.where(label_mask, shifted, 0.0), axis=-1)
    nll = logz - l_y
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits_f, axis=-1) == labels)
                   .astype(jnp.float32))
    total = loss + aux
    return total, {"loss": loss, "aux": aux, "accuracy": acc}


def make_lm_train_step(model: Model, opt_cfg: OptimizerConfig,
                       schedule_fn: Callable):
    """Returns (opt_init, train_step). train_step: (params, opt_state,
    batch, step) -> (params, opt_state, metrics)."""
    opt_init, opt_update = init_optimizer(opt_cfg)
    grad_dtype = jnp.dtype(opt_cfg.grad_dtype)

    def train_step(params, opt_state, batch, step):
        # pin every batch leaf to the data axis at the step boundary so the
        # loss (and its backward) starts from a batch-sharded layout even if
        # the host fed differently-placed arrays; no-op without a mesh
        batch = {k: logical_constraint(v, ("batch",))
                 for k, v in batch.items()}

        def loss_fn(p):
            return lm_loss_and_metrics(model, p, batch)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if grad_dtype != jnp.float32:
            # reduced-precision gradient all-reduce (beyond-paper knob):
            # the data-axis psum happens on these casted leaves.
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(grad_dtype), grads)
        lr = schedule_fn(step)
        new_params, new_opt = opt_update(grads, opt_state, params, lr)
        metrics = dict(metrics, lr=lr)
        return new_params, new_opt, metrics

    return opt_init, train_step


def make_lm_eval_fn(model: Model):
    def eval_fn(params, batch):
        _, metrics = lm_loss_and_metrics(model, params, batch)
        return metrics
    return eval_fn


def make_prefill_fn(model: Model, cache_len: int | None = None):
    def prefill(params, batch):
        return model.prefill(
            params, batch["tokens"], cache_len=cache_len,
            vision_embeds=batch.get("vision_embeds"),
            frames=batch.get("frames"))
    return prefill


def make_decode_fn(model: Model):
    def decode(params, cache, token, pos):
        return model.decode(params, cache, token, pos)
    return decode
