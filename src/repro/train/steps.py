"""Step factories: training, eval, prefill, decode.

All steps are pure functions (params, ...) -> (params, ...) suitable for
jax.jit with explicit in/out shardings; the SWAP controller and the dry-run
both consume them.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.dist.sharding import logical_constraint
from repro.models.model import Model
from repro.optim.api import init_optimizer
from repro.train.precision import (
    PrecisionPolicy, make_precision_train_step, resolve_policy,
)


def lm_loss_and_metrics(model: Model, params, batch: Dict):
    """Cross-entropy next-token loss + router aux; metrics incl. accuracy
    (the paper's phase-1 stopping criterion is TRAIN accuracy)."""
    logits, aux = model.apply(
        params, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        frames=batch.get("frames"))
    labels = batch["labels"]
    # CE without take_along_axis / full f32 logits: gathers over a
    # vocab-sharded logits tensor force GSPMD all-gathers (§Perf iter 1);
    # the masked reduction keeps every op vocab-shardable.
    logits_f = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits_f, axis=-1, keepdims=True))
    shifted = logits_f - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    V = logits.shape[-1]
    label_mask = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1)
                  == labels[..., None])
    l_y = jnp.sum(jnp.where(label_mask, shifted, 0.0), axis=-1)
    nll = logz - l_y
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits_f, axis=-1) == labels)
                   .astype(jnp.float32))
    total = loss + aux
    return total, {"loss": loss, "aux": aux, "accuracy": acc}


def make_lm_train_step(model: Model, opt_cfg: OptimizerConfig,
                       schedule_fn: Callable,
                       policy: Optional[PrecisionPolicy] = None,
                       grad_accum_steps: int = 1):
    """Returns (opt_init, train_step). train_step: (params, opt_state,
    batch, step) -> (params, opt_state, metrics).

    The stateless params-level surface (dry-run AOT lowering, arch smoke
    tests) over the same precision pipeline the adapters use: grads
    unscaled + cast to ``policy.grad_dtype`` before the data-axis psum,
    master f32 optimizer update, optional microbatch accumulation. The
    caller's ``model`` fixes the compute dtype (``ModelConfig.dtype`` —
    built with ``policy.compute_dtype`` for reduced-precision runs, as the
    LM adapter does). The deprecated ``opt_cfg.grad_dtype`` is folded into
    the resolved policy (``resolve_policy``). Dynamic loss scaling is
    stateful and therefore engine-only: drive it through the adapters /
    ``EpochRunner`` (``TrainState.scale``), not this signature."""
    opt_init, opt_update = init_optimizer(opt_cfg)
    policy = policy if policy is not None \
        else resolve_policy("float32", opt_cfg)
    if policy.dynamic:
        raise ValueError(
            "dynamic loss scaling needs the stateful engine step — use "
            "adapter.make_train_step / EpochRunner (TrainState.scale)")

    def loss_with_aux(params, state, batch):
        total, metrics = lm_loss_and_metrics(model, params, batch)
        return total, (metrics, state)

    step5 = make_precision_train_step(
        loss_with_aux, opt_update, schedule_fn, policy=policy,
        grad_accum_steps=grad_accum_steps, cast_inputs=False)
    const_scale = policy.init_scale_state()

    def train_step(params, opt_state, batch, step):
        # pin every batch leaf to the data axis at the step boundary so the
        # loss (and its backward) starts from a batch-sharded layout even if
        # the host fed differently-placed arrays; no-op without a mesh
        batch = {k: logical_constraint(v, ("batch",))
                 for k, v in batch.items()}
        bundle, new_opt, _, metrics = step5(
            {"params": params, "state": {}}, opt_state, batch, step,
            const_scale)
        return bundle["params"], new_opt, metrics

    return opt_init, train_step


def make_lm_eval_fn(model: Model):
    def eval_fn(params, batch):
        _, metrics = lm_loss_and_metrics(model, params, batch)
        return metrics
    return eval_fn


def make_prefill_fn(model: Model, cache_len: int | None = None):
    def prefill(params, batch):
        return model.prefill(
            params, batch["tokens"], cache_len=cache_len,
            vision_embeds=batch.get("vision_embeds"),
            frames=batch.get("frames"))
    return prefill


def make_decode_fn(model: Model):
    def decode(params, cache, token, pos):
        return model.decode(params, cache, token, pos)
    return decode
