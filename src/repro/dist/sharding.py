"""Sharding rules, the ambient mesh, and HLO collective accounting.

This module is the single place that knows how the repo's pytrees map onto
a device mesh. Three groups of exports:

  * **Mesh context** — ``set_mesh`` / ``get_mesh`` hold the ambient mesh;
    ``logical_constraint(x, axes)`` resolves *logical* axis names (``batch``,
    ``heads``, ``experts``, ...) against it and applies a
    ``with_sharding_constraint``. Outside a mesh it is the identity, so model
    code can sprinkle constraints freely and still run on a bare CPU.

  * **Parameter / batch / cache rules** — ``param_spec`` derives a
    ``PartitionSpec`` from a parameter's tree path and shape (FSDP-style:
    matmul weights over ``("data", "model")``, embedding/head contraction
    dims kept OFF the data axis, stacked-block leading dims unsharded, norm
    scales replicated, expert stacks over ``model``). Optimizer-state trees
    mirror their parameters: a leading ``mu/`` / ``nu/`` path component is
    stripped before the rules apply, so state shards exactly like its
    parameter. Every rule goes through a per-dim divisibility check and
    falls back to replication for dims the mesh axis does not divide.

  * **HLO collective accounting** — ``parse_replica_groups`` /
    ``collective_bytes`` read post-SPMD HLO text;
    ``assert_no_cross_worker_collectives`` proves the SWAP phase-2 property
    (Gupta et al., 2020: workers train with *no synchronization*) directly
    on the compiled program: every collective's replica group must stay
    inside one worker's device block.

Axis vocabulary (see docs/sharding.md): mesh axes are ``worker`` (SWAP
phase-2 independence), ``data`` (batch / FSDP), ``model`` (tensor
parallelism) and optionally a leading ``pod``. Logical activation/parameter
axis names resolve to mesh axes through ``LOGICAL_AXIS_RULES``.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# ambient mesh
# ---------------------------------------------------------------------------

_STATE = threading.local()


def get_mesh():
    """The ambient mesh set by ``set_mesh``, or None."""
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh for
    ``logical_constraint`` resolution (thread-local, re-entrant)."""
    prev = get_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


# ---------------------------------------------------------------------------
# logical axis resolution
# ---------------------------------------------------------------------------

# logical name -> mesh axis. Names already equal to a mesh axis resolve to
# themselves; unknown names (or axes missing from the mesh) replicate.
LOGICAL_AXIS_RULES: Dict[str, str] = {
    "batch": "data",
    "embed": "data",      # FSDP: shard the feature dim over the data axis
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "stack": None,
    "seq": None,
}


def _resolve(mesh, axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
    """Resolve logical axis names to a PartitionSpec against ``mesh``.

    Per dim: map the logical name through LOGICAL_AXIS_RULES (identity for
    names that already are mesh axes), then replicate the dim if the mesh
    axis is absent, already used by an earlier dim, or does not divide the
    dim size. Only needs ``mesh.axis_names`` and ``mesh.shape``, so tests
    can pass a lightweight fake mesh.
    """
    names = tuple(mesh.axis_names)
    sizes = dict(mesh.shape)
    axes = tuple(axes) + (None,) * (len(shape) - len(axes))
    used: set = set()
    out: List[Optional[str]] = []
    for ax, dim in zip(axes, shape):
        mesh_ax = LOGICAL_AXIS_RULES.get(ax, ax) if ax is not None else None
        if (mesh_ax is None or mesh_ax not in names or mesh_ax in used
                or dim % sizes[mesh_ax] != 0):
            out.append(None)
        else:
            out.append(mesh_ax)
            used.add(mesh_ax)
    if all(a is None for a in out):
        return P()  # canonical replication, rank-independent
    return P(*out)


def logical_constraint(x, axes: Sequence[Optional[str]]):
    """``with_sharding_constraint(x, axes-resolved-on-the-ambient-mesh)``.

    A no-op (returns ``x`` itself) when no mesh is set, so model code works
    unchanged on a single CPU device and under plain ``vmap``.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = _resolve(mesh, axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# optimizer-state containers whose trees mirror the parameter tree
_OPT_PREFIXES = ("mu", "nu", "m", "v")

# tree-path prefixes that carry stacked leading dims (scan over units):
# blocks/ leaves are (n_units, unit_len, ...); tail/ and encoder/blocks/
# leaves are (n, ...)
_STACK_PREFIXES: Tuple[Tuple[str, int], ...] = (
    ("blocks/", 2),
    ("encoder/blocks/", 1),
    ("tail/", 1),
)


def _strip_opt_prefix(parts: List[str]) -> List[str]:
    while parts and parts[0] in _OPT_PREFIXES:
        parts = parts[1:]
    return parts


def _stack_dims(path: str) -> int:
    for prefix, n in _STACK_PREFIXES:
        if path.startswith(prefix):
            return n
    return 0


def param_spec(name: str, shape: Sequence[int], mesh) -> P:
    """PartitionSpec for a parameter (or optimizer-state mirror) leaf.

    ``name`` is the ``/``-joined tree path. Rules, applied to the *core*
    shape (after the stacked leading dims of ``blocks/`` etc.):

      * scalars, vectors, norm ``scale``/``bias``  -> replicated
      * ``embed/table`` and ``head/w``             -> (None, ..., "model")
        — the contraction dim stays OFF the data axis so the head matmul
        resolves by gathering weights, not partial-summing activations
      * MoE expert stacks (``moe/wi|wg|wo``)       -> ("experts", None, None)
        — expert-parallel over the model axis, dense per expert shard
      * any other weight with >= 2 core dims       -> (..., "data", "model")

    Every rule passes through the divisibility fallback of ``_resolve``.
    """
    parts = _strip_opt_prefix([p for p in name.split("/") if p])
    path = "/".join(parts)
    leaf = parts[-1] if parts else ""
    n_stack = _stack_dims(path)
    core = tuple(shape[n_stack:])

    if len(core) <= 1 or leaf in ("scale", "bias"):
        return P()
    if path == "embed/table" or path.endswith("head/w"):
        # contraction dim OFF the data axis: only the output/feature dim
        # shards (over model), so the head matmul gathers weights instead of
        # partial-summing activations across data
        axes: Tuple[Optional[str], ...] = \
            (None,) * (len(core) - 1) + ("model",)
    elif ("moe/" in path or path.startswith("moe")) and len(core) == 3:
        # (n_experts, d_in, d_out) expert stacks: expert-parallel over the
        # model axis, dense per expert shard (matches the activation
        # constraint ("batch", "experts", None, None) in models/moe.py)
        axes = ("experts",) + (None,) * (len(core) - 1)
    else:
        axes = (None,) * (len(core) - 2) + ("embed", "heads")
    spec = _resolve(mesh, (None,) * n_stack + axes, shape)
    if all(a is None for a in spec):
        return P()
    return spec


def path_str(path) -> str:
    """Flatten a tree_map_with_path key path to the '/'-joined rule key."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_shardings(mesh, tree):
    """NamedSharding tree mirroring ``tree`` (params OR optimizer state)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path_str(path), leaf.shape, mesh)),
        tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_shardings(mesh, tree):
    """Batch leaves shard their leading dim over ``data`` (with divisibility
    fallback); everything else replicates."""
    def leaf_sharding(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, _resolve(mesh, ("batch",), leaf.shape))
    return jax.tree_util.tree_map(leaf_sharding, tree)


def cache_batch_dim(path: str) -> int:
    """Batch-dim position of a KV/SSM-cache leaf: leaves under the stacked
    ``units`` subtree carry the unit axis first, so batch is dim 1."""
    return 1 if path.split("/", 1)[0] == "units" else 0


def page_pool_dim(path: str) -> Optional[int]:
    """Page-dim position of a paged KV-pool leaf, or None for per-slot
    (dense) cache leaves.

    Paged layers store their KV under a ``p`` layout key (vs ``a`` for
    dense) — a global ``(n_pages, page_size, ...)`` pool shared by every
    slot, indexed through per-slot block tables. The pool has no batch
    dim; the shardable resident-state dim is the PAGE dim, which sits
    where the batch dim would (dim 1 under the stacked ``units`` subtree,
    dim 0 elsewhere)."""
    parts = path.split("/")
    if len(parts) >= 2 and parts[-2] == "p":
        return 1 if parts[0] == "units" else 0
    return None


def data_axes(tree):
    """Pytree of ints: which dim of each leaf is the batch/data dim.

    0 for plain batch leaves, 1 for stacked-unit cache leaves — the same
    rule the serving engine uses to scatter per-request cache rows.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_batch_dim(path_str(path)), tree)


def cache_shardings(mesh, tree, batch: Optional[int] = None):
    """Decode-cache shardings: the batch dim (position given by
    ``cache_batch_dim``) goes on ``data``; paged-pool leaves shard their
    PAGE dim (``page_pool_dim``) on ``data`` instead — pages, like slots,
    are the unit of resident serving state. All other dims replicate."""
    def leaf_sharding(path, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        ps = path_str(path)
        pd = page_pool_dim(ps)
        bd = cache_batch_dim(ps) if pd is None else pd
        if bd >= leaf.ndim:
            return NamedSharding(mesh, P())
        axes: List[Optional[str]] = [None] * leaf.ndim
        axes[bd] = "batch"
        return NamedSharding(mesh, _resolve(mesh, axes, leaf.shape))
    return jax.tree_util.tree_map_with_path(leaf_sharding, tree)


def ensemble_shardings(mesh, tree):
    """SWAP phase-2 stacked-bundle shardings: the leading worker axis of
    every stacked leaf goes on the mesh ``worker`` axis; per-worker content
    replicates inside the worker block (the block's own data/model sharding
    is applied by in-step ``logical_constraint``s)."""
    def leaf_sharding(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _resolve(mesh, ("worker",), leaf.shape))
    return jax.tree_util.tree_map(leaf_sharding, tree)


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_GROUPS_LIST_RE = re.compile(
    r"replica_groups=\{(\{[0-9, ]*\}(?:,\s*\{[0-9, ]*\})*)\}")
_PAIRS_RE = re.compile(
    r"source_target_pairs=\{(\{[0-9, ]*\}(?:,\s*\{[0-9, ]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Sum output bytes of every collective in HLO text, keyed by kind.

    Matches ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
    ``all-to-all`` / ``collective-permute`` (async ``-start`` forms count
    once; ``-done`` forms are skipped to avoid double counting). Bytes come
    from the instruction's *output* shape(s), which is what crosses the
    interconnect per device.
    """
    out: Dict[str, int] = {}
    for line in hlo.splitlines():
        for kind in _COLLECTIVE_KINDS:
            m = re.search(rf"[\s=]{re.escape(kind)}(-start)?\(", line)
            if m is None:
                continue
            lhs = line[:m.start() + 1]
            if "=" not in lhs:
                continue
            shapes = _SHAPE_RE.findall(lhs.split("=", 1)[1])
            if m.group(1) and len(shapes) >= 2:
                # async form: the output tuple is (operand(s), result(s),
                # [context scalars]) — only the result half crosses the wire
                shapes = shapes[len(shapes) // 2:]
            nbytes = sum(_tensor_bytes(dt, dims) for dt, dims in shapes)
            if nbytes:
                out[kind] = out.get(kind, 0) + nbytes
            break
    return out


def parse_replica_groups(hlo: str) -> List[List[int]]:
    """All replica groups in HLO text, in both syntaxes:

      * explicit lists:  ``replica_groups={{0,1},{2,3}}``
      * iota form:       ``replica_groups=[G,S]<=[dims]`` with an optional
        transpose ``T(perm)`` — expand ``arange(prod(dims)).reshape(dims)
        .transpose(perm).reshape(G, S)``, one group per row.
    """
    groups: List[List[int]] = []
    for m in _GROUPS_LIST_RE.finditer(hlo):
        for body in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            ids = [int(t) for t in body.replace(" ", "").split(",") if t]
            if ids:
                groups.append(ids)
    for m in _GROUPS_IOTA_RE.finditer(hlo):
        gshape = [int(t) for t in m.group(1).split(",") if t]
        dims = [int(t) for t in m.group(2).split(",") if t]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(3):
            perm = [int(t) for t in m.group(3).split(",") if t]
            ids = ids.transpose(perm)
        ids = ids.reshape(gshape[0], -1)
        groups.extend(ids.astype(int).tolist())
    return groups


def parse_source_target_pairs(hlo: str) -> List[List[int]]:
    """All ``collective-permute`` ``source_target_pairs={{s,t},...}`` in HLO
    text, one ``[source, target]`` pair per entry. Permutes carry pairs, not
    ``replica_groups`` — a cross-worker check must read both."""
    pairs: List[List[int]] = []
    for m in _PAIRS_RE.finditer(hlo):
        for body in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            ids = [int(t) for t in body.replace(" ", "").split(",") if t]
            if ids:
                pairs.append(ids)
    return pairs


def assert_no_cross_worker_collectives(hlo: str, n_workers: int,
                                       devices_per_worker: int) -> int:
    """Assert every collective replica group stays inside one worker block.

    Worker ``w`` owns the contiguous device ids
    ``[w*devices_per_worker, (w+1)*devices_per_worker)`` (the worker axis is
    outermost in the mesh device order — see ``launch.mesh.make_worker_mesh``).
    This is the paper's phase-2 property, checked on the compiled program:
    a group straddling two blocks means the partitioner synchronized
    workers. ``collective-permute`` communicates through
    ``source_target_pairs`` rather than ``replica_groups``; each pair is
    checked the same way, and an empty ``replica_groups={}`` (XLA's "one
    group of ALL replicas") counts as a group spanning every device.
    Raises AssertionError explicitly (not a bare ``assert``) so the
    guarantee survives ``python -O``. Returns the number of groups + pairs
    checked.
    """
    groups = parse_replica_groups(hlo) + parse_source_target_pairs(hlo)
    n_all_replica = len(re.findall(r"replica_groups=\{\}", hlo))
    if n_all_replica and n_workers > 1:
        all_devices = list(range(n_workers * devices_per_worker))
        groups += [all_devices] * n_all_replica
    for group in groups:
        owners = {device // devices_per_worker for device in group}
        if len(owners) > 1:
            raise AssertionError(
                f"collective replica group {group} spans workers "
                f"{sorted(owners)} (n_workers={n_workers}, "
                f"devices_per_worker={devices_per_worker}): SWAP phase-2 "
                f"workers must not synchronize")
    return len(groups)
