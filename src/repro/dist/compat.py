"""JAX-version compatibility shims for the sharding subsystem.

The repo pins JAX 0.4.37, but the sharding call sites (and the seed test
suite) were written against the newer explicit-sharding surface:

  * ``jax.sharding.AxisType`` (enum with ``Auto`` / ``Explicit`` / ``Manual``)
    — does not exist in 0.4.37;
  * ``jax.make_mesh(shape, names, axis_types=...)`` — 0.4.37's ``make_mesh``
    rejects the ``axis_types`` keyword;
  * ``jax.set_mesh(mesh)`` — the ambient-mesh context manager.

On 0.4.37 every mesh axis is implicitly Auto (GSPMD decides placements), so
``axis_types=(AxisType.Auto, ...)`` carries no information and can be
accepted and dropped. ``install()`` patches exactly that — it never changes
behaviour on a JAX new enough to have the real API.
"""
from __future__ import annotations

import enum
import functools

import jax


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (added after 0.4.37)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _wrap_make_mesh(make_mesh):
    # only installed when make_mesh's signature lacks axis_types, so the
    # kwarg is always dropped: on 0.4.37 every axis is Auto anyway
    @functools.wraps(make_mesh)
    def wrapped(axis_shapes, axis_names, *args, axis_types=None, **kwargs):
        del axis_types
        return make_mesh(axis_shapes, axis_names, *args, **kwargs)

    wrapped.__wrapped_for_axis_types__ = True
    return wrapped


def install() -> None:
    """Idempotently install the shims onto the ``jax`` namespace."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not getattr(jax.make_mesh, "__wrapped_for_axis_types__", False):
        import inspect

        # signature probe only — never instantiate a mesh at import time
        params = inspect.signature(jax.make_mesh).parameters
        if "axis_types" not in params:
            jax.make_mesh = _wrap_make_mesh(jax.make_mesh)
    if not hasattr(jax, "set_mesh"):
        # our ambient-mesh context (resolved by logical_constraint)
        from repro.dist import sharding

        jax.set_mesh = sharding.set_mesh
