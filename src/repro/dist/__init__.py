"""Distribution subsystem: meshes, sharding rules, HLO collective checks.

Importing this package installs the small jax compatibility shims (see
``repro.dist.compat``) needed to run the sharding API on the pinned
JAX 0.4.37 — callers that create meshes with ``axis_types=`` get them
accepted (and ignored) instead of a ``TypeError``.
"""
from repro.dist import compat as _compat

_compat.install()

from repro.dist import sharding  # noqa: E402,F401
from repro.dist.config import (  # noqa: E402,F401
    DistConfig, add_dist_args, parse_mesh, resolve_dist,
)
from repro.dist.sharding import (  # noqa: E402,F401
    assert_no_cross_worker_collectives,
)
