"""File-based per-worker heartbeat liveness.

The elastic phase-3 machinery (``repro.core.averaging.ElasticAverage``)
consumes per-worker *arrival* timestamps: how late each worker's
contribution is relative to the averaging deadline. PR 9 fed it simulated
arrivals (``launch.train --lost-workers``); this module supplies real
ones, using the same medium the multi-host result exchange already uses —
the filesystem (``tests/test_multihost.py``): shared-filesystem clusters
are exactly the deployments this repo's ``jax.distributed`` path targets,
and files need no extra coordinator process.

Protocol
--------
Each worker (or host, in the one-writer-per-host deployment) atomically
rewrites a single beacon file ``hb-worker<N>.json`` at chunk boundaries:

    {"worker": N, "seq": k, "t": <clock seconds>, "step": <train step>}

``atomic_write`` (write-then-rename) guarantees a monitor never reads a
torn beacon. The monitor derives everything from beacon staleness at poll
time:

  * **live mask** — a worker is live iff its beacon exists and is no
    staler than ``timeout_s``;
  * **elastic arrivals** — a live worker's arrival is its staleness
    (``now - last beat``): a prompt worker arrives ~0, a slow-but-alive
    one arrives late enough to exercise the deadline backoff, and a dead
    one (stale beyond ``timeout_s`` or never seen) arrives ``inf`` and is
    dropped from the average.

Both sides take an injectable ``clock`` so the chaos suite
(``repro.testing.faults.FakeClock``) can script deterministic timelines —
no sleeps-as-synchronization anywhere.

Knobs live on ``DistConfig``: ``heartbeat_dir`` (enables the subsystem),
``heartbeat_interval_s`` (min spacing between beats), and
``heartbeat_timeout_s`` (staleness that declares a worker dead; 0 derives
3x the interval). See docs/resilience.md.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint.io import atomic_write

_INF = float("inf")


def heartbeat_path(directory: str, worker: int) -> str:
    return os.path.join(directory, f"hb-worker{int(worker)}.json")


class HeartbeatWriter:
    """One worker's beacon. ``beat`` always writes; ``maybe_beat`` respects
    ``interval_s`` so chunk-boundary hooks on fast chunks don't hammer the
    shared filesystem."""

    def __init__(self, directory: str, worker: int,
                 interval_s: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        if interval_s < 0:
            raise ValueError(f"interval_s must be >= 0, got {interval_s}")
        self.directory = directory
        self.worker = int(worker)
        self.interval_s = float(interval_s)
        self.clock = clock
        self.seq = 0
        self._last_beat: Optional[float] = None
        os.makedirs(directory, exist_ok=True)

    @property
    def path(self) -> str:
        return heartbeat_path(self.directory, self.worker)

    def beat(self, step: Optional[int] = None) -> None:
        now = float(self.clock())
        self.seq += 1
        atomic_write(self.path, json.dumps(
            {"worker": self.worker, "seq": self.seq, "t": now,
             "step": None if step is None else int(step)}).encode())
        self._last_beat = now

    def maybe_beat(self, step: Optional[int] = None) -> bool:
        now = float(self.clock())
        if (self._last_beat is not None
                and now - self._last_beat < self.interval_s):
            return False
        self.beat(step)
        return True


class HeartbeatMonitor:
    """Reads every worker's beacon and turns staleness into liveness and
    elastic arrivals. Stateless between polls apart from the directory —
    a monitor can come up after a crash and immediately see the truth."""

    def __init__(self, directory: str, n_workers: int, timeout_s: float,
                 clock: Callable[[], float] = time.monotonic):
        if n_workers <= 0:
            raise ValueError(f"n_workers must be positive, got {n_workers}")
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self.directory = directory
        self.n_workers = int(n_workers)
        self.timeout_s = float(timeout_s)
        self.clock = clock

    def poll(self) -> Dict[int, Optional[dict]]:
        """Latest beacon per worker id (None: never beat / unreadable).
        A torn or half-written beacon is impossible by construction
        (atomic_write), but a beacon damaged out-of-band reads as absent
        rather than crashing the monitor."""
        out: Dict[int, Optional[dict]] = {}
        for w in range(self.n_workers):
            try:
                with open(heartbeat_path(self.directory, w)) as f:
                    rec = json.load(f)
                out[w] = rec if isinstance(rec, dict) else None
            except (OSError, json.JSONDecodeError):
                out[w] = None
        return out

    def staleness(self, now: Optional[float] = None) -> List[float]:
        """Seconds since each worker's last beat (inf: never seen)."""
        now = float(self.clock()) if now is None else float(now)
        beacons = self.poll()
        out = []
        for w in range(self.n_workers):
            rec = beacons[w]
            if rec is None or "t" not in rec:
                out.append(_INF)
            else:
                out.append(max(0.0, now - float(rec["t"])))
        return out

    def live_mask(self, now: Optional[float] = None) -> np.ndarray:
        """Boolean (n_workers,): live iff staleness <= timeout_s."""
        stale = self.staleness(now)
        return np.asarray([s <= self.timeout_s for s in stale], bool)

    def dead_among(self, workers: Sequence[int],
                   now: Optional[float] = None) -> List[int]:
        """The subset of ``workers`` currently past the liveness timeout."""
        mask = self.live_mask(now)
        return [int(w) for w in workers if not mask[int(w)]]

    def arrivals(self, workers: Optional[Sequence[int]] = None,
                 now: Optional[float] = None) -> List[float]:
        """Elastic arrival seconds for ``workers`` (default: all), aligned
        with the order given — the shape ``elastic_average_stacked``
        expects. Staleness-as-lateness: a live worker 'arrives' as late as
        its beacon is stale (so a straggling-but-alive worker can exceed
        the elastic deadline and exercise the backoff), and a dead worker
        arrives inf and is dropped from the average."""
        stale = self.staleness(now)
        if workers is None:
            workers = range(self.n_workers)
        out = []
        for w in workers:
            s = stale[int(w)]
            out.append(s if s <= self.timeout_s else _INF)
        return out


def beat_on_chunk(writers: Sequence[HeartbeatWriter]):
    """A ``run_phase`` chunk hook that beats every writer (in-process
    deployments where one launcher drives all workers). Multi-process
    deployments instead give each process its own writer and call
    ``maybe_beat`` from their own loops."""
    def hook(state, done):
        step = int(np.asarray(state.step).reshape(-1)[0])
        for w in writers:
            w.maybe_beat(step=step)
    return hook
