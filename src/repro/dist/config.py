"""DistConfig: the one distribution-surface dataclass.

Every mesh/sharding/worker knob that used to be threaded through ``SWAP``,
``SGDRun``, ``EpochRunner`` and the serving engines as loose kwargs lives
here as a first-class field (the alpa ``global_env.py`` config object is
the exemplar: mesh options, resharding mode, donation policy as named
knobs rather than call-site arguments). One frozen dataclass describes

  * mesh geometry        — ``mesh_shape`` / ``mesh_axes`` (pure data, so the
    config is hashable and JSON round-trippable; ``make_mesh()`` builds the
    runtime ``jax.sharding.Mesh`` from whatever devices exist),
  * the phase-2 engine   — ``phase2_engine``: "sharded" lowers the ensemble
    epoch as ONE sharded-jit program (``vmap(..., spmd_axis_name='worker')``
    with pinned in/out shardings — the worker axis of every intermediate is
    fixed in the partitioner, which is what keeps the lowering free of
    cross-worker collectives); "vmap" is the plain single-device vmap that
    stays as the bitwise equivalence oracle; "auto" picks "sharded" iff the
    mesh has a worker axis,
  * donation policy      — ``donate_state``: whether epoch chunks donate the
    input TrainState buffers (off for debugging / keeping references),
  * elastic averaging    — ``elastic_deadline_s`` (> 0 turns the strict
    phase-3 barrier into a deadline: the average folds whichever workers
    report in time), ``elastic_backoff`` / ``elastic_max_extensions``
    (straggler timeout growth while fewer than ``elastic_min_workers``
    reported) — see ``repro.core.averaging.ElasticAverage``,
  * multi-host layout    — ``coordinator`` / ``num_processes`` /
    ``process_id`` feed ``jax.distributed.initialize``; ``initialize()``
    is the launcher entry point.

The CLI flag surface (``add_dist_args`` / ``DistConfig.from_args``) and the
programmatic API expose identical knobs, and ``from_json``/``to_json``
round-trip a config through a file so a launch can be replayed exactly.

Back-compat: callers that still pass ``mesh=`` get a ``DeprecationWarning``
shim (``resolve_dist``) for one release — the mesh object keeps working and
a DistConfig is derived from its geometry.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

_ENGINES = ("auto", "sharded", "vmap")

# rank -> default axis names for bare "2x2x2"-style mesh specs
_DEFAULT_AXES = {
    1: ("data",),
    2: ("data", "model"),
    3: ("worker", "data", "model"),
    4: ("pod", "worker", "data", "model"),
}


def parse_mesh(spec: str) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Parse a ``--mesh`` spec into (shape, axes).

    Two syntaxes:
      * named:  ``worker:2,data:2,model:2``
      * bare:   ``2x2x2`` — axes default by rank (1d: data; 2d: data,model;
        3d: worker,data,model; 4d: pod,worker,data,model)
    """
    spec = spec.strip()
    if not spec:
        return (), ()
    if ":" in spec:
        shape, axes = [], []
        for part in spec.split(","):
            name, _, size = part.partition(":")
            if not name.strip() or not size.strip():
                raise ValueError(f"bad mesh axis {part!r} in {spec!r} "
                                 f"(want name:size)")
            axes.append(name.strip())
            shape.append(int(size))
        return tuple(shape), tuple(axes)
    sizes = tuple(int(t) for t in spec.lower().split("x"))
    if len(sizes) not in _DEFAULT_AXES:
        raise ValueError(
            f"bare mesh spec {spec!r} has rank {len(sizes)}; use the named "
            f"form (e.g. 'worker:2,data:4') for ranks outside "
            f"{sorted(_DEFAULT_AXES)}")
    return sizes, _DEFAULT_AXES[len(sizes)]


@dataclass(frozen=True)
class DistConfig:
    """The unified distribution config (see module docstring)."""

    # mesh geometry — () means "no mesh": single-device / plain-vmap paths
    mesh_shape: Tuple[int, ...] = ()
    mesh_axes: Tuple[str, ...] = ()
    n_workers: int = 1

    # phase-2 engine + donation policy
    phase2_engine: str = "auto"        # "auto" | "sharded" | "vmap"
    donate_state: bool = True

    # elastic averaging (0 = strict: phase 3 waits for every worker)
    elastic_deadline_s: float = 0.0
    elastic_backoff: float = 2.0
    elastic_max_extensions: int = 2
    elastic_min_workers: int = 1

    # multi-host (jax.distributed)
    coordinator: str = ""              # "host:port"; "" = single process
    num_processes: int = 1
    process_id: int = 0

    # heartbeat liveness ("" = disabled: elastic arrivals stay
    # caller-supplied / simulated). See repro.dist.heartbeat.
    heartbeat_dir: str = ""
    heartbeat_interval_s: float = 0.0  # min spacing between beats
    heartbeat_timeout_s: float = 0.0   # staleness = dead; 0 derives below

    def __post_init__(self):
        if len(self.mesh_shape) != len(self.mesh_axes):
            raise ValueError(
                f"mesh_shape {self.mesh_shape} and mesh_axes "
                f"{self.mesh_axes} must have equal rank")
        if self.phase2_engine not in _ENGINES:
            raise ValueError(f"phase2_engine must be one of {_ENGINES}, "
                             f"got {self.phase2_engine!r}")
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.elastic_deadline_s < 0:
            raise ValueError("elastic_deadline_s must be >= 0")
        if self.elastic_backoff < 1.0:
            raise ValueError("elastic_backoff must be >= 1 (the deadline "
                             "never shrinks)")
        if self.elastic_max_extensions < 0:
            raise ValueError("elastic_max_extensions must be >= 0")
        if not (1 <= self.elastic_min_workers <= self.n_workers):
            raise ValueError(
                f"elastic_min_workers must be in [1, n_workers="
                f"{self.n_workers}], got {self.elastic_min_workers}")
        if self.num_processes < 1:
            raise ValueError("num_processes must be >= 1")
        if not (0 <= self.process_id < self.num_processes):
            raise ValueError(
                f"process_id {self.process_id} out of range for "
                f"num_processes {self.num_processes}")
        if self.num_processes > 1 and not self.coordinator:
            raise ValueError("multi-host (num_processes > 1) needs a "
                             "coordinator address ('host:port')")
        if self.heartbeat_interval_s < 0:
            raise ValueError("heartbeat_interval_s must be >= 0")
        if self.heartbeat_timeout_s < 0:
            raise ValueError("heartbeat_timeout_s must be >= 0")
        if (self.heartbeat_timeout_s > 0 and self.heartbeat_interval_s > 0
                and self.heartbeat_timeout_s < self.heartbeat_interval_s):
            raise ValueError(
                f"heartbeat_timeout_s ({self.heartbeat_timeout_s}) must be "
                f">= heartbeat_interval_s ({self.heartbeat_interval_s}): a "
                f"timeout shorter than the beat spacing declares every "
                f"worker dead between beats")

    # ------------------------------------------------------------------
    # derived properties
    # ------------------------------------------------------------------

    @property
    def elastic(self) -> bool:
        return self.elastic_deadline_s > 0

    @property
    def multihost(self) -> bool:
        return self.num_processes > 1

    @property
    def heartbeats(self) -> bool:
        return bool(self.heartbeat_dir)

    @property
    def resolved_heartbeat_timeout(self) -> float:
        """Liveness timeout in seconds: the explicit knob, else 3 beat
        intervals (one missed beat is a hiccup, three is a death), else a
        30s default for interval-less (beat-every-boundary) setups."""
        if self.heartbeat_timeout_s > 0:
            return self.heartbeat_timeout_s
        if self.heartbeat_interval_s > 0:
            return 3.0 * self.heartbeat_interval_s
        return 30.0

    @property
    def has_worker_axis(self) -> bool:
        return "worker" in self.mesh_axes

    @property
    def data_shard(self) -> Optional[Tuple[int, int]]:
        """Per-host data shard for ``repro.data.pipeline.Loader``:
        ``(process_id, num_processes)`` so each host materializes only its
        slice of every global batch; None for single-process runs."""
        return (self.process_id, self.num_processes) if self.multihost \
            else None

    def resolved_engine(self, mesh=None) -> str:
        """'sharded' or 'vmap'. 'auto' resolves to 'sharded' exactly when a
        mesh with a worker axis is in play."""
        if self.phase2_engine != "auto":
            return self.phase2_engine
        has_worker = ("worker" in mesh.axis_names) if mesh is not None \
            else self.has_worker_axis
        return "sharded" if has_worker else "vmap"

    # ------------------------------------------------------------------
    # runtime construction
    # ------------------------------------------------------------------

    def make_mesh(self):
        """Build the runtime Mesh from ``mesh_shape``/``mesh_axes`` over the
        devices that exist, or None when no mesh is configured. The worker
        axis (when present) must be outermost in ``mesh_axes`` so worker w
        owns a contiguous device-id block (the collective-audit contract,
        see ``dist.sharding.assert_no_cross_worker_collectives``)."""
        if not self.mesh_shape:
            return None
        if "worker" in self.mesh_axes and self.mesh_axes[0] != "worker" \
                and self.mesh_axes[0] != "pod":
            raise ValueError(
                f"the worker axis must be outermost (after an optional pod "
                f"axis) so each worker owns a contiguous device block; got "
                f"axes {self.mesh_axes}")
        import jax
        return jax.make_mesh(
            self.mesh_shape, self.mesh_axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(self.mesh_axes))

    def initialize(self) -> None:
        """``jax.distributed.initialize`` for multi-host runs; a no-op for
        single-process configs. Must run before the first jax device query
        in the process (the launchers call it first thing)."""
        if not self.multihost:
            return
        import jax
        jax.distributed.initialize(
            coordinator_address=self.coordinator,
            num_processes=self.num_processes,
            process_id=self.process_id)

    @classmethod
    def from_mesh(cls, mesh, **overrides) -> "DistConfig":
        """Derive a DistConfig from an existing Mesh's geometry (the
        ``mesh=`` deprecation shim path)."""
        axes = tuple(mesh.axis_names)
        shape = tuple(int(mesh.shape[a]) for a in axes)
        kw = dict(mesh_shape=shape, mesh_axes=axes)
        if "worker" in axes:
            kw["n_workers"] = int(mesh.shape["worker"])
        kw.update(overrides)
        return cls(**kw)

    # ------------------------------------------------------------------
    # JSON round trip
    # ------------------------------------------------------------------

    def to_json(self, path: Optional[str] = None) -> str:
        """Serialize to a JSON string; also write it to ``path`` if given."""
        text = json.dumps(dataclasses.asdict(self), indent=1)
        if path:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_json(cls, src: str) -> "DistConfig":
        """Load from a JSON string or a path to a JSON file. Unknown keys
        are rejected (a typoed knob must not silently default)."""
        if os.path.exists(src):
            with open(src) as f:
                src = f.read()
        data = json.loads(src)
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - fields
        if unknown:
            raise ValueError(f"unknown DistConfig keys {sorted(unknown)}; "
                             f"known: {sorted(fields)}")
        for key in ("mesh_shape", "mesh_axes"):
            if key in data:
                data[key] = tuple(data[key])
        return cls(**data)

    # ------------------------------------------------------------------
    # CLI flag surface (shared by launch.train / launch.serve / examples)
    # ------------------------------------------------------------------

    @classmethod
    def from_args(cls, args, n_workers_default: int = 1) -> "DistConfig":
        """Build from an argparse namespace produced by ``add_dist_args``.

        ``--dist-config FILE`` loads a base config; explicitly-passed flags
        override it (a flag left at its parser default defers to the file).
        """
        base = cls.from_json(args.dist_config) if args.dist_config else cls()
        kw = dict(
            (f.name, getattr(base, f.name)) for f in dataclasses.fields(cls))
        if args.mesh is not None:
            kw["mesh_shape"], kw["mesh_axes"] = parse_mesh(args.mesh)
        if args.workers is not None:
            kw["n_workers"] = args.workers
        elif not args.dist_config:
            kw["n_workers"] = n_workers_default
        if args.phase2_engine is not None:
            kw["phase2_engine"] = args.phase2_engine
        if args.elastic_deadline is not None:
            kw["elastic_deadline_s"] = args.elastic_deadline
        if args.elastic_backoff is not None:
            kw["elastic_backoff"] = args.elastic_backoff
        if args.elastic_min_workers is not None:
            kw["elastic_min_workers"] = args.elastic_min_workers
        if args.coordinator is not None:
            kw["coordinator"] = args.coordinator
        if args.num_processes is not None:
            kw["num_processes"] = args.num_processes
        if args.process_id is not None:
            kw["process_id"] = args.process_id
        if args.heartbeat_dir is not None:
            kw["heartbeat_dir"] = args.heartbeat_dir
        if args.heartbeat_interval is not None:
            kw["heartbeat_interval_s"] = args.heartbeat_interval
        if args.heartbeat_timeout is not None:
            kw["heartbeat_timeout_s"] = args.heartbeat_timeout
        return cls(**kw)


def add_dist_args(parser) -> None:
    """Install the unified DistConfig flag surface on an argparse parser.
    Defaults are all None so ``DistConfig.from_args`` can tell 'not passed'
    from 'passed the default value' (file-config overrides stay correct)."""
    g = parser.add_argument_group(
        "distribution (repro.dist.DistConfig; identical to the "
        "programmatic surface)")
    g.add_argument("--mesh", default=None, metavar="SPEC",
                   help="device mesh: 'worker:2,data:2,model:2' or '2x2x2' "
                        "(bare rank-3 means worker,data,model); omit for "
                        "single-device / plain-vmap execution")
    g.add_argument("--workers", type=int, default=None,
                   help="SWAP phase-2 worker count (DistConfig.n_workers)")
    g.add_argument("--phase2-engine", default=None,
                   choices=["auto", "sharded", "vmap"],
                   help="phase-2 lowering: one sharded-jit program over the "
                        "worker mesh axis, the plain-vmap oracle, or auto "
                        "(sharded iff the mesh has a worker axis)")
    g.add_argument("--elastic-deadline", type=float, default=None,
                   metavar="SECONDS",
                   help="elastic phase-3 averaging: fold whichever workers "
                        "report within this deadline (0 = strict barrier)")
    g.add_argument("--elastic-backoff", type=float, default=None,
                   help="deadline growth factor while fewer than "
                        "--elastic-min-workers reported (default 2.0)")
    g.add_argument("--elastic-min-workers", type=int, default=None,
                   help="fewest live workers an elastic average may fold "
                        "(all-late past the backed-off deadline is an error)")
    g.add_argument("--dist-config", default="", metavar="FILE",
                   help="load a DistConfig JSON file "
                        "(DistConfig.from_json); explicit flags override it")
    g.add_argument("--dump-dist-config", default="", metavar="FILE",
                   help="write the resolved DistConfig to FILE "
                        "(DistConfig.to_json) and continue")
    g.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                   help="jax.distributed coordinator address (multi-host)")
    g.add_argument("--num-processes", type=int, default=None,
                   help="total jax.distributed processes (multi-host)")
    g.add_argument("--process-id", type=int, default=None,
                   help="this process's jax.distributed index (multi-host)")
    g.add_argument("--heartbeat-dir", default=None, metavar="DIR",
                   help="shared directory for per-worker heartbeat beacons "
                        "(repro.dist.heartbeat); enables real liveness in "
                        "place of simulated elastic arrivals")
    g.add_argument("--heartbeat-interval", type=float, default=None,
                   metavar="SECONDS",
                   help="minimum spacing between heartbeats (0 = beat at "
                        "every chunk boundary)")
    g.add_argument("--heartbeat-timeout", type=float, default=None,
                   metavar="SECONDS",
                   help="beacon staleness that declares a worker dead "
                        "(0 = 3x the interval, or 30s)")


def resolve_dist(dist: Optional[DistConfig] = None, mesh=None, *,
                 caller: str = "caller"):
    """Resolve the (dist=, mesh=) pair every surface accepts into
    ``(DistConfig, Optional[Mesh])``.

    ``mesh=`` is the deprecated spelling: it still works for one release
    (the passed Mesh object is used as-is and a DistConfig is derived from
    its geometry) but warns. Passing both is an error — a mesh that
    disagrees with the config would silently win."""
    if mesh is not None and dist is not None:
        raise ValueError(
            f"{caller}: pass dist= (DistConfig) or the deprecated mesh=, "
            f"not both")
    if mesh is not None:
        warnings.warn(
            f"{caller}(mesh=...) is deprecated; pass "
            f"dist=DistConfig.from_mesh(mesh) (or a hand-built DistConfig) "
            f"instead. The mesh= spelling will be removed next release.",
            DeprecationWarning, stacklevel=3)
        return DistConfig.from_mesh(mesh), mesh
    if dist is None:
        return DistConfig(), None
    return dist, dist.make_mesh()
