"""Continuous-batching serving engine (vLLM-style slots, JAX-native).

A fixed pool of `max_batch` slots shares ONE decode program and ONE
pre-allocated cache of length `max_seq`. Requests are admitted into free
slots as they arrive (prompt prefilled with batch=1 and scattered into the
slot), every engine step decodes ALL active slots at their own positions
(per-request position vectors — see models/attention.py), and finished
requests free their slot immediately for the next waiting request. No
recompilation happens after warmup: the decode program is shape-stable.

Inactive slots decode garbage into their own slot region; their outputs are
masked and their cache rows are re-prefilled on admission, so they cannot
contaminate live requests (asserted in tests against single-request
generation, token-exact).

NOTE: this per-step engine is the EQUIVALENCE ORACLE and bench baseline
for ``repro.serve.compiled.CompiledServingEngine`` (one fused K-token
decode per host call, device-resident slot state, jitted admission).
Production serving should use the compiled engine; this one dispatches one
jitted step per Python iteration and blocks on a per-slot ``int()`` sync
for every generated token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import cache_batch_dim, path_str
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray                  # (S,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # weight generation the request was admitted under (compiled engine
    # with live publishing; None on the per-step oracle, which serves one
    # static param set)
    generation: Optional[int] = None
    # admission deadline (compiled engine): seconds from submit within
    # which the request must be ADMITTED, else it is shed with
    # rejected=True / done=True instead of holding the head of the queue
    # on an exhausted page pool. None defers to the engine-level
    # admit_timeout_s (None there = wait indefinitely, the legacy
    # behavior). submit_t is stamped by the engine's clock at submit().
    deadline_s: Optional[float] = None
    submit_t: Optional[float] = None
    rejected: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, dist=None):
        # dist (repro.dist.DistConfig) is accepted for surface parity with
        # CompiledServingEngine but ignored: this engine is the per-step
        # single-device token-exact oracle — mesh placement belongs to the
        # compiled engine it validates.
        self.model = model
        self.dist = dist
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = model.empty_cache(max_batch, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.positions = jnp.zeros((max_batch,), jnp.int32)  # next write pos
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)   # next input
        self.waiting: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode(p, c, t, pos))
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, cache_len=max_seq))

    # ------------------------------------------------------------------

    def submit(self, request: Request) -> None:
        S = request.prompt.shape[0]
        if S > self.max_seq:
            raise ValueError(
                f"prompt of {S} tokens cannot fit the engine cache "
                f"(max_seq={self.max_seq})")
        self.waiting.append(request)
        self._admit()

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        # re-derive free slots every iteration: a request that finishes AT
        # admission (via _maybe_finish below) leaves its slot free for the
        # next waiting request in this same pass
        while self.waiting:
            free = self._free_slots()
            if not free:
                return
            slot = free[0]
            req = self.waiting.pop(0)
            S = req.prompt.shape[0]
            logits, pc = self._prefill(self.params,
                                       req.prompt[None, :].astype(jnp.int32))
            self._insert_cache(pc, slot)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)  # (1,)
            self.tokens = self.tokens.at[slot, 0].set(tok[0])
            self.positions = self.positions.at[slot].set(S)
            req.generated = [int(tok[0])]
            self.slot_req[slot] = req
            self._maybe_finish(slot)

    def _insert_cache(self, prefill_cache, slot: int) -> None:
        """Scatter a batch=1 prefill cache into the engine cache slot."""
        flat_engine = jax.tree_util.tree_flatten_with_path(self.cache)
        flat_new = jax.tree_util.tree_flatten(prefill_cache)[0]
        leaves = []
        for (path, dst), src in zip(flat_engine[0], flat_new):
            # the cache's batch-dim layout is owned by repro.dist (the same
            # rule cache_shardings uses to put the batch dim on `data`)
            bd = cache_batch_dim(path_str(path))
            idx = [slice(None)] * dst.ndim
            idx[bd] = slot
            src_row = jnp.take(src.astype(dst.dtype), 0, axis=bd)
            leaves.append(dst.at[tuple(idx)].set(src_row))
        self.cache = jax.tree_util.tree_unflatten(flat_engine[1], leaves)

    def _maybe_finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is None:
            return
        if (len(req.generated) >= req.max_new_tokens
                or (req.eos_id is not None and req.generated
                    and req.generated[-1] == req.eos_id)
                or int(self.positions[slot]) >= self.max_seq - 1):
            req.done = True
            self.slot_req[slot] = None

    # ------------------------------------------------------------------

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def step(self) -> None:
        """One decode step for all active slots."""
        if self.active == 0:
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.positions)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)    # (B,)
        self.tokens = next_tok[:, None]
        # advance ACTIVE slots only (mirrors CompiledServingEngine._advance):
        # free/finished slots must freeze, or an idle slot's position drifts
        # without bound and its garbage writes clamp into row max_seq-1
        active = jnp.asarray([r is not None for r in self.slot_req])
        self.positions = jnp.where(active, self.positions + 1,
                                   self.positions)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                req.generated.append(int(next_tok[slot]))
                self._maybe_finish(slot)
        self._admit()

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> Dict[int, List[int]]:
        """Serve a list of requests to completion; returns rid -> tokens."""
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.active or self.waiting) and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: r.generated for r in requests}
