"""Live weight publishing: the continuous train→serve loop.

SWAP's product is the *averaged* model (Algorithm 1, lines 27-28), and the
paper's production story is a model that keeps improving while it serves.
This module closes that loop:

  * ``WeightPublisher`` — an epoch-boundary hook for the phase engine
    (``repro.train.loop.run_phase``'s ``on_chunk`` surface): at each chunk
    boundary it folds the current across-worker parameter mean into a
    ``StreamingAverage`` over epochs (the online-averaging schedule of
    Izmailov et al. SWA, applied to SWAP's phase-2 ensemble), then pushes
    the new running average — a new weight *generation* — into live
    ``CompiledServingEngine`` replicas via ``engine.publish`` and/or an
    atomic publish snapshot (``repro.checkpoint.state.save_publish``).

  * ``PublishFollower`` — the consumer side for engines in OTHER
    processes: tail a checkpoint directory for new publish generations
    (``launch.serve --follow``). Atomic write-then-rename means a poll can
    never observe a torn generation; a publisher killed mid-write is
    simply invisible until it completes.

The swap itself is the engine's job (double-buffered device params,
per-slot generation pinning — see ``repro.serve.compiled``); the publisher
only decides WHAT to publish and WHEN. In-process publishing moves one
host->device params transfer per generation and zero extra device->host
syncs, so the engine's single-transfer-per-decode-call invariant holds
across swaps.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.state import (
    find_latest_publish, load_publish, save_publish, state_step,
)
from repro.core.averaging import StreamingAverage, average_stacked

# the transient failure modes a publish retry can actually fix: I/O
# hiccups on the snapshot dir, an engine's delivery raising. Programming
# errors (TypeError, bad trees) are not retried.
_RETRYABLE = (OSError, RuntimeError, ValueError)


class WeightPublisher:
    """Epoch-boundary snapshot + atomic hot-swap of the running average.

    ``engines``: live ``CompiledServingEngine`` instances to swap in
    process. ``directory``: optional checkpoint dir for atomic publish
    snapshots (cross-process consumers follow it with ``PublishFollower``).
    ``ensemble``: the hooked phase carries a leading worker axis (SWAP
    phase 2) that is averaged across before folding; set False when
    publishing from a single-model phase. ``every``: publish each
    ``every``-th epoch boundary (1 = every chunk).

    Use ``publisher.on_epoch`` as a ``run_phase``/``SWAP.run`` hook, or
    call ``publish(params)`` directly with an already-averaged tree.

    Delivery resilience: ``max_retries`` re-attempts a failed publish
    (snapshot write or engine delivery raising) with exponential backoff
    (``retry_backoff_s * 2**k``, via an injectable ``sleep``). After the
    budget, ``on_failure`` decides: ``"raise"`` (default — the failure
    propagates exactly as without retries, and the generation counter
    never advanced) or ``"skip"`` (record in ``self.failures``, warn, and
    return the current generation — training proceeds and the NEXT epoch
    boundary publishes a fresher average anyway, so one lost delivery
    costs staleness, not the run).
    """

    def __init__(self, engines=(), *, directory: Optional[str] = None,
                 ensemble: bool = True, every: int = 1, impl: str = "auto",
                 max_retries: int = 0, retry_backoff_s: float = 0.05,
                 on_failure: str = "raise",
                 sleep: Callable[[float], None] = time.sleep):
        if not engines and not directory:
            raise ValueError(
                "WeightPublisher needs somewhere to publish: pass live "
                "engines, a snapshot directory, or both")
        if on_failure not in ("raise", "skip"):
            raise ValueError(f"on_failure must be 'raise' or 'skip', "
                             f"got {on_failure!r}")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        self.engines: List[Any] = list(engines)
        self.directory = directory
        self.ensemble = ensemble
        self.every = max(1, every)
        self.average = StreamingAverage(impl=impl)
        self.generation = 0
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.on_failure = on_failure
        self._sleep = sleep
        self._boundaries = 0
        self.log: List[Dict[str, int]] = []   # [{generation, step, folds}]
        self.failures: List[Dict[str, Any]] = []   # skipped publishes

    def attach(self, engine) -> None:
        """Add a live engine; it receives generations published later."""
        self.engines.append(engine)

    # -- run_phase hook surface (state, steps_done) ---------------------

    def on_epoch(self, state, done: int) -> Optional[int]:
        """Fold this epoch boundary's model into the running average and
        publish it. Signature matches ``run_phase(on_chunk=...)`` hooks;
        attach via ``SWAP.run(phase2_hooks=[publisher.on_epoch])``."""
        self._boundaries += 1
        if self._boundaries % self.every:
            return None
        params = state.bundle["params"]
        if self.ensemble:
            # across-worker mean first (phase 3's average_stacked), then
            # the across-epoch streaming fold — online SWA over SWAP
            params = average_stacked(params)
        avg = self.average.add(params)
        return self.publish(avg, step=state_step(state))

    # -- direct publishing ----------------------------------------------

    def publish(self, params, step: int = 0) -> int:
        """Publish ``params`` as the next generation: atomic snapshot
        first (so a crash mid-publish never leaves an engine ahead of the
        durable record), then hot-swap into every attached engine.

        The generation counter and log only advance once the publish
        actually lands somewhere: a ``save_publish`` failure propagates
        (after the retry budget) without consuming a generation number,
        and if every attached engine rejects the generation as stale
        (``publish`` -> None) the counter rolls back too — otherwise a
        flaky snapshot dir or a restarted publisher racing a fresher one
        would burn generations and log publishes that never happened.

        Retries re-run the whole attempt (snapshot + delivery) under the
        SAME generation number — ``save_publish`` is an atomic overwrite,
        so a half-delivered retry can never fork generation history."""
        attempt = 0
        while True:
            try:
                return self._publish_once(params, step)
            except _RETRYABLE as err:
                attempt += 1
                if attempt <= self.max_retries:
                    self._sleep(self.retry_backoff_s * 2 ** (attempt - 1))
                    continue
                if self.on_failure == "raise":
                    raise
                self.failures.append(
                    {"step": step, "attempts": attempt,
                     "error": f"{type(err).__name__}: {err}"})
                warnings.warn(
                    f"publish at step {step} failed after {attempt} "
                    f"attempt(s) ({err}); skipping — the next epoch "
                    f"boundary publishes a fresher average",
                    RuntimeWarning)
                return self.generation

    def _publish_once(self, params, step: int) -> int:
        gen = self.generation + 1
        if self.directory:
            save_publish(self.directory, gen, step, params,
                         meta={"folds": self.average.n})
        delivered = not self.engines
        for engine in self.engines:
            # engines: True = swapped now, False = deferred (will apply),
            # None = rejected as stale — only non-None counts as delivery
            if engine.publish(params, generation=gen) is not None:
                delivered = True
        if not delivered:
            return self.generation                # all engines rejected
        self.generation = gen
        self.log.append({"generation": gen, "step": step,
                         "folds": self.average.n})
        return gen


class PublishFollower:
    """Tail a checkpoint directory for new publish generations.

    ``poll()`` returns ``(generation, params)`` when a generation newer
    than the last seen one is fully visible, else None. Because publishes
    are write-then-rename with the sidecar written before the snapshot, a
    torn write is never returned — the follower just sees the previous
    generation until the new one completes.
    """

    def __init__(self, directory: str, template):
        self.directory = directory
        self.template = template
        self.generation = 0        # newest generation already consumed

    def poll(self) -> Optional[Tuple[int, Any]]:
        latest = find_latest_publish(self.directory)
        if latest is None or latest["generation"] <= self.generation:
            return None
        params = load_publish(latest["path"], self.template)
        self.generation = latest["generation"]
        return latest["generation"], params
