from repro.serve.compiled import (CompiledServingEngine, DecodeState,
                                  decode_state_shardings, default_buckets)
from repro.serve.engine import Request, ServingEngine

__all__ = ["CompiledServingEngine", "DecodeState", "Request",
           "ServingEngine", "decode_state_shardings", "default_buckets"]
