from repro.serve.compiled import (CompiledServingEngine, DecodeState,
                                  decode_state_shardings, default_buckets)
from repro.serve.engine import Request, ServingEngine
from repro.serve.publish import PublishFollower, WeightPublisher

__all__ = ["CompiledServingEngine", "DecodeState", "PublishFollower",
           "Request", "ServingEngine", "WeightPublisher",
           "decode_state_shardings", "default_buckets"]
