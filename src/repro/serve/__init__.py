from repro.serve.engine import Request, ServingEngine
