"""Compiled continuous-batching engine: the serving analogue of the PR-3
scan-based training engine.

The per-step python ``ServingEngine`` (repro.serve.engine — kept as the
token-exact equivalence oracle and bench baseline) dispatches ONE jitted
decode per Python iteration and then blocks on ``int(next_tok[slot])`` for
every active slot — B×1 host syncs per generated token — and rebuilds the
whole cache pytree on the host at every admission. This engine moves the
hot loop under one compile:

  * **Device-resident scheduler state.** Slot state (next tokens, write
    positions, active flags, remaining-token budgets, per-slot EOS ids,
    sampling rng) lives on device as a ``DecodeState`` pytree alongside
    the cache. The host keeps only the request queue and a replay mirror.

  * **Fused multi-token decode.** One jit runs a ``lax.scan`` of
    ``decode_block`` (K) model steps: sampling (argmax or categorical),
    EOS detection, per-slot stopping, position/budget bookkeeping, and
    token buffering all happen on device. The host receives a single bulk
    ``(max_batch, K)`` token block per call — zero per-token round-trips —
    and replays the device's stop rule from that block alone.

  * **Jitted bulk admission.** A prefilled batch=1 cache is scattered into
    an engine slot with ``dynamic_update_slice`` over each leaf's batch
    dim — the dim named by ``repro.dist.cache_batch_dim``, the same rule
    ``cache_shardings`` uses to put that dim on the ``data`` mesh axis —
    replacing the old host-side leaf-by-leaf pytree rebuild.

  * **Bucketed prefill.** Prompts are right-padded to a small set of
    bucket lengths (``Model.prefill(length=...)`` makes the padding exact:
    same logits, window slots, and SSM states as the unpadded prompt), so
    warmup compiles a fixed program set instead of one program per
    distinct prompt length.

Scheduling differs from the oracle — admissions only happen between
K-token blocks, so a freed slot can idle for up to K-1 steps — but each
request's TOKENS are exact: a slot's output depends only on its own cache
rows, which admission re-prefills (asserted per-request against both the
python engine and single-request generation in tests/test_serve_compiled).

  * **Live weight publishing.** ``publish(params)`` hot-swaps a new weight
    generation (e.g. the phase-2 running average from
    ``repro.serve.publish.WeightPublisher``) without dropping in-flight
    requests: params are double-buffered on device, every slot is pinned
    to the generation it was admitted under, and while two generations are
    live the fused loop evaluates both and selects per-slot (bitwise — a
    swap never perturbs an admitted request's tokens). New admissions pick
    up the latest generation; the swap itself is pure host bookkeeping +
    one async host->device params transfer, so ``decode_transfers ==
    decode_calls`` holds across swaps (tests/test_publish.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import (batch_shardings, cache_batch_dim,
                                 cache_shardings, path_str)
from repro.models.model import Model
from repro.serve.engine import Request


class DecodeState(NamedTuple):
    """Device-resident scheduler state (a pytree; one leaf set per slot)."""

    cache: Any               # model KV/SSM cache, batch dim = slots
    tokens: jnp.ndarray      # (B,) int32 — next input token per slot
    positions: jnp.ndarray   # (B,) int32 — cache position `tokens` writes to
    active: jnp.ndarray      # (B,) bool  — slot currently generating
    remaining: jnp.ndarray   # (B,) int32 — decode steps left in the budget
    eos: jnp.ndarray         # (B,) int32 — per-slot EOS id, -1 = none
    rng: jnp.ndarray         # PRNG key for categorical sampling


def decode_state_shardings(mesh, state: DecodeState) -> DecodeState:
    """NamedSharding tree for a DecodeState: cache leaves by the
    ``cache_batch_dim`` rule, per-slot vectors batch-sharded, rng
    replicated — so a multi-host serving mesh places slots on ``data``."""
    vec_sh = batch_shardings(
        mesh, {"tokens": state.tokens, "positions": state.positions,
               "active": state.active, "remaining": state.remaining,
               "eos": state.eos})
    return DecodeState(
        cache=cache_shardings(mesh, state.cache),
        rng=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        **vec_sh)


def default_buckets(max_seq: int, lo: int = 16) -> Tuple[int, ...]:
    """Doubling prompt-length buckets: lo, 2lo, ... capped at max_seq."""
    buckets: List[int] = []
    b = lo
    while b < max_seq:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq)
    return tuple(buckets)


class CompiledServingEngine:
    """Drop-in sibling of ``ServingEngine`` with a compiled hot loop.

    Args beyond the oracle's: ``decode_block`` (K — model steps fused per
    host call), ``prefill_buckets`` (padded prompt lengths; None = doubling
    set from ``default_buckets``), ``sample`` ("greedy" | "categorical"),
    ``temperature`` and ``rng`` for sampling.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, decode_block: int = 8,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 sample: str = "greedy", temperature: float = 1.0,
                 rng=None, generation: int = 0):
        if sample not in ("greedy", "categorical"):
            raise ValueError(f"unknown sample mode {sample!r}")
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.decode_block = decode_block
        self.sample = sample
        self.temperature = temperature
        # double-buffered device-resident param sets: slot j of _buffers
        # holds weight generation _buf_gen[j]; _latest names the buffer new
        # admissions pin to. publish() fills the inactive buffer, so an
        # in-flight request keeps decoding on the exact weights it was
        # admitted under (see _decode_k_dual).
        self._buffers: List[Any] = [params, None]
        self._buf_gen: List[int] = [generation, generation - 1]
        self._latest: int = 0
        self._pending: Optional[Tuple[int, Any]] = None
        self.buckets = tuple(sorted(prefill_buckets)) \
            if prefill_buckets else default_buckets(max_seq)
        self.state = self._empty_state(
            rng if rng is not None else jax.random.PRNGKey(0))
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_len: List[int] = [0] * max_batch   # prompt len per slot
        self.slot_buf: List[int] = [0] * max_batch   # pinned param buffer
        self.waiting: List[Request] = []
        # instrumentation consumed by benchmarks/bench_serve.py and
        # bench_publish.py: the zero-per-token-round-trip claim is
        # `decode_transfers == decode_calls` (one bulk block fetch per
        # fused call) — publishes must not add host syncs
        self.stats: Dict[str, int] = {
            "decode_calls": 0, "decode_transfers": 0, "decode_steps": 0,
            "admissions": 0, "admit_transfers": 0, "prefill_compiles": 0,
            "publishes": 0, "publish_swaps": 0, "publish_superseded": 0,
            "dual_decode_calls": 0,
        }
        self._prefill_fn = jax.jit(
            lambda p, t, L: model.prefill(p, t, cache_len=max_seq, length=L))
        self._admit_fn = jax.jit(self._admit_device, donate_argnums=(0,))
        self._decode_fn = jax.jit(self._decode_k, donate_argnums=(1,))
        self._decode_dual_fn = jax.jit(self._decode_k_dual,
                                       donate_argnums=(2,))

    @property
    def params(self):
        """The latest published parameter set (what new admissions use)."""
        return self._buffers[self._latest]

    @property
    def generation(self) -> int:
        """Weight generation new admissions are pinned to."""
        return self._buf_gen[self._latest]

    # ------------------------------------------------------------------
    # device programs
    # ------------------------------------------------------------------

    def _empty_state(self, rng) -> DecodeState:
        B = self.max_batch
        return DecodeState(
            cache=self.model.empty_cache(B, self.max_seq),
            tokens=jnp.zeros((B,), jnp.int32),
            positions=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool),
            remaining=jnp.zeros((B,), jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
            rng=rng)

    def _sample(self, logits, key):
        """(B, vocab) logits -> (B,) int32 next tokens."""
        if self.sample == "greedy":
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature,
            axis=-1).astype(jnp.int32)

    def _admit_device(self, state: DecodeState, prefill_cache, first_tok,
                      slot, length, budget, eos_id, active) -> DecodeState:
        """Scatter a batch=1 prefill cache + fresh slot scalars into
        ``slot``. One compiled program for every admission (prefill caches
        are always padded to ``max_seq``)."""
        def scatter(path, dst, src):
            # the cache's batch-dim layout is owned by repro.dist — the
            # same rule cache_shardings uses to put the batch dim on `data`
            bd = cache_batch_dim(path_str(path))
            start = [jnp.int32(0)] * dst.ndim
            start[bd] = slot
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), tuple(start))

        cache = jax.tree_util.tree_map_with_path(
            scatter, state.cache, prefill_cache)
        return DecodeState(
            cache=cache,
            tokens=state.tokens.at[slot].set(first_tok),
            positions=state.positions.at[slot].set(length),
            active=state.active.at[slot].set(active),
            remaining=state.remaining.at[slot].set(budget),
            eos=state.eos.at[slot].set(eos_id),
            rng=state.rng)

    def _advance(self, st: DecodeState, logits, cache):
        """Shared per-step bookkeeping after the model evaluation(s):
        sample, then mirror the oracle's step — positions advance, budgets
        tick, and a slot stops on budget, EOS, or max_seq-1 truncation,
        all checked AFTER the position increment, like
        ServingEngine._maybe_finish. Finished/free slots freeze so their
        (garbage) rows never index out of bounds. Identical ops in the
        single- and dual-generation programs, so tokens are bitwise
        independent of which program decoded them."""
        max_seq = self.max_seq
        rng, key = jax.random.split(st.rng)
        next_tok = self._sample(logits, key)
        act = st.active
        pos1 = jnp.where(act, st.positions + 1, st.positions)
        rem1 = jnp.where(act, st.remaining - 1, st.remaining)
        hit_eos = (st.eos >= 0) & (next_tok == st.eos)
        done = (rem1 <= 0) | hit_eos | (pos1 >= max_seq - 1)
        return DecodeState(
            cache=cache,
            tokens=jnp.where(act, next_tok, st.tokens),
            positions=pos1,
            active=act & ~done,
            remaining=rem1,
            eos=st.eos,
            rng=rng), next_tok

    def _decode_k(self, params, state: DecodeState):
        """K fused decode steps under one jit. Returns (state, (B, K) token
        block) — the block is the ONLY device->host traffic per call."""
        model = self.model

        def body(st: DecodeState, _):
            logits, cache = model.decode(params, st.cache,
                                         st.tokens[:, None], st.positions)
            return self._advance(st, logits, cache)

        state, toks = jax.lax.scan(body, state, None,
                                   length=self.decode_block)
        return state, toks.T                      # (K, B) -> (B, K)

    def _decode_k_dual(self, params_a, params_b, state: DecodeState, use_b):
        """K fused decode steps with TWO weight generations resident:
        every slot's logits and cache rows come from the param set its
        request was admitted under — ``jnp.where`` SELECTS between the two
        evaluations (never blends), so an in-flight request's tokens are
        bitwise identical to a single-generation engine pinned at its
        admission weights. Costs two model evaluations per step; the host
        dispatches this program only while generations are actually mixed
        (the old one drains as its requests finish). Still one bulk (B, K)
        transfer per call — publishing adds no host syncs."""
        model = self.model

        def body(st: DecodeState, _):
            logits_a, cache_a = model.decode(params_a, st.cache,
                                             st.tokens[:, None], st.positions)
            logits_b, cache_b = model.decode(params_b, st.cache,
                                             st.tokens[:, None], st.positions)
            logits = jnp.where(use_b[:, None], logits_b, logits_a)

            def pick(path, a, b):
                # broadcast the per-slot selector along each cache leaf's
                # batch dim — the dim owned by the repro.dist rule
                bd = cache_batch_dim(path_str(path))
                shape = [1] * a.ndim
                shape[bd] = a.shape[bd]
                return jnp.where(use_b.reshape(shape), b, a)

            cache = jax.tree_util.tree_map_with_path(pick, cache_a, cache_b)
            return self._advance(st, logits, cache)

        state, toks = jax.lax.scan(body, state, None,
                                   length=self.decode_block)
        return state, toks.T

    # ------------------------------------------------------------------
    # host scheduler
    # ------------------------------------------------------------------

    def _bucket(self, S: int) -> int:
        for b in self.buckets:
            if b >= S:
                return b
        return S              # buckets capped below max_seq: exact-length

    def submit(self, request: Request) -> None:
        S = request.prompt.shape[0]
        if S > self.max_seq:
            raise ValueError(
                f"prompt of {S} tokens cannot fit the engine cache "
                f"(max_seq={self.max_seq})")
        self.waiting.append(request)
        self._admit()

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        # re-derive free slots every iteration: a request that finishes AT
        # admission (budget 1 / instant EOS / truncation) leaves its slot
        # free for the next waiting request in this same pass; a deferred
        # publish is retried each iteration too, so a request admitted
        # after the blocking slot freed picks up the newest generation
        self._apply_pending()
        while self.waiting:
            self._apply_pending()
            free = self._free_slots()
            if not free:
                return
            slot = free[0]
            req = self.waiting.pop(0)
            S = req.prompt.shape[0]
            bucket = self._bucket(S)
            padded = jnp.pad(req.prompt[None, :].astype(jnp.int32),
                             ((0, 0), (0, bucket - S)))
            logits, pc = self._prefill_fn(self.params, padded,
                                          jnp.int32(S))
            if self.sample == "greedy":
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[0]
            else:
                self.state, key = self._split_host_key()
                tok = jax.random.categorical(
                    key, logits.astype(jnp.float32)
                    / self.temperature, axis=-1).astype(jnp.int32)[0]
            t0 = int(tok)                         # one scalar per ADMISSION
            self.stats["admissions"] += 1
            self.stats["admit_transfers"] += 1
            req.generated = [t0]
            req.generation = self.generation      # pinned for its lifetime
            done0 = (req.max_new_tokens <= 1
                     or (req.eos_id is not None and t0 == req.eos_id)
                     or S >= self.max_seq - 1)
            self.state = self._admit_fn(
                self.state, pc, tok, jnp.int32(slot), jnp.int32(S),
                jnp.int32(req.max_new_tokens - 1), jnp.int32(
                    -1 if req.eos_id is None else req.eos_id),
                jnp.asarray(not done0))
            if done0:
                req.done = True
            else:
                self.slot_req[slot] = req
                self.slot_len[slot] = S
                self.slot_buf[slot] = self._latest

    def _split_host_key(self):
        rng, key = jax.random.split(self.state.rng)
        return self.state._replace(rng=rng), key

    # ------------------------------------------------------------------
    # live weight publishing
    # ------------------------------------------------------------------

    def publish(self, params, generation: Optional[int] = None) -> bool:
        """Queue ``params`` as the next weight generation and swap it in as
        soon as the inactive buffer is free of pinned in-flight requests
        (often immediately). In-flight requests keep decoding on their
        admission-time weights; new admissions pick up the new generation.

        Only the newest queued publish survives — if another lands before
        a deferred one applied, the older is superseded (counted in
        ``stats['publish_superseded']``). Returns True when the swap
        happened inside this call, False when deferred (it will apply
        between decode calls once the old generation drains) or stale
        (``generation`` not newer than what the engine already serves).
        """
        base = self._buf_gen[self._latest]
        if self._pending is not None:
            base = max(base, self._pending[0])     # don't collide with a
        gen = base + 1 if generation is None else int(generation)  # queued gen
        if gen <= self._buf_gen[self._latest]:
            return False                          # stale republish
        if self._pending is not None:
            if gen <= self._pending[0]:
                return False
            self.stats["publish_superseded"] += 1
        self.stats["publishes"] += 1
        self._pending = (gen, params)
        return self._apply_pending()

    def _apply_pending(self) -> bool:
        """Swap the pending params into the inactive buffer unless a live
        request still pins it (double-buffering invariant: a buffer is
        only overwritten once no in-flight request can read it)."""
        if self._pending is None:
            return False
        target = 1 - self._latest
        if any(r is not None and self.slot_buf[i] == target
               for i, r in enumerate(self.slot_req)):
            return False                          # deferred: buffer busy
        gen, params = self._pending
        ref = self._buffers[self._latest]

        def place(new, old):
            new = jnp.asarray(new, getattr(old, "dtype", None))
            if new.shape != old.shape:
                raise ValueError(
                    f"published params have leaf shape {new.shape} where "
                    f"the engine expects {old.shape} — generation "
                    f"published from a different model config?")
            return new

        # cast to the resident dtypes/shapes so the compiled decode
        # programs are reused as-is (a publish must never recompile)
        self._buffers[target] = jax.tree_util.tree_map(place, params, ref)
        self._buf_gen[target] = gen
        self._latest = target
        self._pending = None
        self.stats["publish_swaps"] += 1
        return True

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def step(self) -> None:
        """One fused K-token decode call for all slots, then a single bulk
        host transfer and a host-side replay of the device stop rule.

        The host knows which param buffer every active slot is pinned to
        (its replay mirror), so choosing the single- vs dual-generation
        program needs no device sync: the common case (all slots on one
        generation) runs exactly the pre-publishing program."""
        if self.active == 0:
            return
        bufs = {self.slot_buf[i] for i, r in enumerate(self.slot_req)
                if r is not None}
        if len(bufs) == 1:
            self.state, block = self._decode_fn(
                self._buffers[bufs.pop()], self.state)
        else:
            use_b = jnp.asarray(
                [b == 1 for b in self.slot_buf])       # async, tiny, h->d
            self.state, block = self._decode_dual_fn(
                self._buffers[0], self._buffers[1], self.state, use_b)
            self.stats["dual_decode_calls"] += 1
        self.stats["decode_calls"] += 1
        self.stats["decode_steps"] += self.decode_block
        block = np.asarray(block)                 # ONE (B, K) transfer
        self.stats["decode_transfers"] += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            for k in range(self.decode_block):
                t = int(block[slot, k])
                req.generated.append(t)
                n = len(req.generated)
                pos_after = self.slot_len[slot] + n - 1
                if (n >= req.max_new_tokens
                        or (req.eos_id is not None and t == req.eos_id)
                        or pos_after >= self.max_seq - 1):
                    req.done = True
                    self.slot_req[slot] = None
                    break
        self._admit()

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> tokens."""
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.active or self.waiting) and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: r.generated for r in requests}

    # ------------------------------------------------------------------

    def warmup(self, dual: bool = False) -> None:
        """Compile the fixed program set (one prefill per bucket, the
        admission scatter, the fused decode block) before serving.
        ``dual=True`` additionally compiles the two-generation decode
        program, so the first mid-flight publish pays no compile — pass it
        when the engine will receive live weight swaps."""
        dummy = jnp.zeros((1, self.buckets[0]), jnp.int32)
        _, pc = self._prefill_fn(self.params, dummy, jnp.int32(1))
        for b in self.buckets[1:]:
            self._prefill_fn(self.params, jnp.zeros((1, b), jnp.int32),
                             jnp.int32(1))
        self.stats["prefill_compiles"] += len(self.buckets)
        st = self._empty_state(jax.random.PRNGKey(0))
        st = self._admit_fn(st, pc, jnp.int32(0), jnp.int32(0),
                            jnp.int32(1), jnp.int32(0), jnp.int32(-1),
                            jnp.asarray(False))
        st, _ = self._decode_fn(self.params, st)
        if dual:
            other = self._buffers[1 - self._latest]
            st, _ = self._decode_dual_fn(
                self.params, other if other is not None else self.params,
                st, jnp.zeros((self.max_batch,), bool))
        jax.block_until_ready(st.tokens)
