"""Compiled continuous-batching engine: the serving analogue of the PR-3
scan-based training engine.

The per-step python ``ServingEngine`` (repro.serve.engine — kept as the
token-exact equivalence oracle and bench baseline) dispatches ONE jitted
decode per Python iteration and then blocks on ``int(next_tok[slot])`` for
every active slot — B×1 host syncs per generated token — and rebuilds the
whole cache pytree on the host at every admission. This engine moves the
hot loop under one compile:

  * **Device-resident scheduler state.** Slot state (next tokens, write
    positions, active flags, remaining-token budgets, per-slot EOS ids,
    sampling rng) lives on device as a ``DecodeState`` pytree alongside
    the cache. The host keeps only the request queue and a replay mirror.

  * **Fused multi-token decode.** One jit runs a ``lax.scan`` of
    ``decode_block`` (K) model steps: sampling (argmax or categorical),
    EOS detection, per-slot stopping, position/budget bookkeeping, and
    token buffering all happen on device. The host receives a single bulk
    ``(max_batch, K)`` token block per call — zero per-token round-trips —
    and replays the device's stop rule from that block alone.

  * **Jitted bulk admission.** A prefilled batch=1 cache is scattered into
    an engine slot with ``dynamic_update_slice`` over each leaf's batch
    dim — the dim named by ``repro.dist.cache_batch_dim``, the same rule
    ``cache_shardings`` uses to put that dim on the ``data`` mesh axis —
    replacing the old host-side leaf-by-leaf pytree rebuild.

  * **Bucketed prefill.** Prompts are right-padded to a small set of
    bucket lengths (``Model.prefill(length=...)`` makes the padding exact:
    same logits, window slots, and SSM states as the unpadded prompt), so
    warmup compiles a fixed program set instead of one program per
    distinct prompt length.

Scheduling differs from the oracle — admissions only happen between
K-token blocks, so a freed slot can idle for up to K-1 steps — but each
request's TOKENS are exact: a slot's output depends only on its own cache
rows, which admission re-prefills (asserted per-request against both the
python engine and single-request generation in tests/test_serve_compiled).

  * **Live weight publishing.** ``publish(params)`` hot-swaps a new weight
    generation (e.g. the phase-2 running average from
    ``repro.serve.publish.WeightPublisher``) without dropping in-flight
    requests: params are double-buffered on device, every slot is pinned
    to the generation it was admitted under, and while two generations are
    live the fused loop evaluates both and selects per-slot (bitwise — a
    swap never perturbs an admitted request's tokens). New admissions pick
    up the latest generation; the swap itself is pure host bookkeeping +
    one async host->device params transfer, so ``decode_transfers ==
    decode_calls`` holds across swaps (tests/test_publish.py).

  * **Paged KV cache.** With ``kv_layout="paged"`` (the default resolution
    of ``"auto"`` whenever the model has full-attention GQA layers), KV
    lives in a global device page pool plus per-slot block tables instead
    of one dense ``max_seq`` slab per slot: admission allocates only the
    pages the prompt needs, decode appends pages on demand (host-side
    allocation between fused calls — a tiny async h->d block-table upload,
    never a d->h sync), and a freed request returns its pages immediately.
    Memory then caps concurrency by RESIDENT TOKENS, not by
    slots x max_seq; ``kv_cache_dtype="int8"`` quantizes the pool
    (symmetric per-(token, head), models/attention.py) for ~4x more
    resident tokens per byte. Non-pageable layers (sliding-window, SSM,
    MLA, cross) keep their dense layout in the same cache tree; the page
    pool's layout/placement is owned by ``repro.dist.page_pool_dim``.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import (batch_shardings, cache_batch_dim,
                                 cache_shardings, page_pool_dim,
                                 param_shardings, path_str)
from repro.models.model import Model
from repro.serve.engine import Request


class DecodeState(NamedTuple):
    """Device-resident scheduler state (a pytree; one leaf set per slot)."""

    cache: Any               # model KV/SSM cache, batch dim = slots
    tokens: jnp.ndarray      # (B,) int32 — next input token per slot
    positions: jnp.ndarray   # (B,) int32 — cache position `tokens` writes to
    active: jnp.ndarray      # (B,) bool  — slot currently generating
    remaining: jnp.ndarray   # (B,) int32 — decode steps left in the budget
    eos: jnp.ndarray         # (B,) int32 — per-slot EOS id, -1 = none
    rng: jnp.ndarray         # PRNG key for categorical sampling
    block_tables: jnp.ndarray  # (B, M) int32 page ids; (B, 0) when dense


def decode_state_shardings(mesh, state: DecodeState) -> DecodeState:
    """NamedSharding tree for a DecodeState: cache leaves by the
    ``cache_batch_dim`` / ``page_pool_dim`` rules, per-slot vectors (and
    block tables) batch-sharded, rng replicated — so a multi-host serving
    mesh places slots and pool pages on ``data``."""
    vec_sh = batch_shardings(
        mesh, {"tokens": state.tokens, "positions": state.positions,
               "active": state.active, "remaining": state.remaining,
               "eos": state.eos, "block_tables": state.block_tables})
    return DecodeState(
        cache=cache_shardings(mesh, state.cache),
        rng=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        **vec_sh)


def default_buckets(max_seq: int, lo: int = 16) -> Tuple[int, ...]:
    """Doubling prompt-length buckets: lo, 2lo, ... capped at max_seq."""
    buckets: List[int] = []
    b = lo
    while b < max_seq:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq)
    return tuple(buckets)


class CompiledServingEngine:
    """Drop-in sibling of ``ServingEngine`` with a compiled hot loop.

    Args beyond the oracle's: ``decode_block`` (K — model steps fused per
    host call), ``prefill_buckets`` (padded prompt lengths; None = doubling
    set from ``default_buckets``; always completed with ``max_seq`` so no
    prompt falls back to an uncounted exact-length compile), ``sample``
    ("greedy" | "categorical"), ``temperature`` and ``rng`` for sampling.

    Paged-cache args: ``kv_layout`` — "dense" (one max_seq cache row per
    slot), "paged" (global page pool + per-slot block tables for the
    model's pageable attention layers), or "auto" (paged iff the model has
    any pageable layer); ``page_size`` (tokens per page); ``n_pages``
    (pool size incl. the reserved null page 0; None = dense-equivalent
    capacity, so admission never waits on pages by default);
    ``kv_cache_dtype`` — overrides the model config's KV dtype (e.g.
    "int8") by rebuilding the Model on an updated config, so prefill,
    decode and the pool all quantize identically.

    Degradation args: ``admit_timeout_s`` — engine-wide bound on how long
    a request may wait for ADMISSION (a free slot + reservable pages);
    a request still waiting past it is shed with ``rejected=True`` /
    ``done=True`` and counted in ``stats["rejections"]``, instead of
    parking the FIFO head on an exhausted page pool forever. Per-request
    ``Request.deadline_s`` overrides it; None everywhere keeps the legacy
    wait-indefinitely behavior. ``clock`` is injectable (chaos tests use
    a fake clock; deadlines never sleep — they are checked at submit/step
    boundaries).
    """

    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, decode_block: int = 8,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 sample: str = "greedy", temperature: float = 1.0,
                 rng=None, generation: int = 0,
                 kv_layout: str = "auto", page_size: int = 16,
                 n_pages: Optional[int] = None,
                 kv_cache_dtype: Optional[str] = None,
                 dist=None, admit_timeout_s: Optional[float] = None,
                 clock=time.monotonic):
        if sample not in ("greedy", "categorical"):
            raise ValueError(f"unknown sample mode {sample!r}")
        if kv_layout not in ("auto", "paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_cache_dtype is not None \
                and kv_cache_dtype != model.cfg.kv_cache_dtype:
            # rebuild on the updated config so EVERY path (prefill scatter,
            # in-loop decode writes, pool leaves) quantizes the same way
            model = Model(dataclasses.replace(
                model.cfg, kv_cache_dtype=kv_cache_dtype))
        # dist (repro.dist.DistConfig): serving-mesh placement. Params land
        # by param_spec rules, decode state (cache + slot vectors) by
        # decode_state_shardings — slots and pool pages on `data`. None
        # (the default) keeps the single-device layout.
        if admit_timeout_s is not None and admit_timeout_s <= 0:
            raise ValueError(
                f"admit_timeout_s must be positive (None = no bound), "
                f"got {admit_timeout_s}")
        self.admit_timeout_s = admit_timeout_s
        self._clock = clock
        self.dist = dist
        self.mesh = dist.make_mesh() if dist is not None else None
        if self.mesh is not None:
            params = jax.device_put(
                params, param_shardings(self.mesh, params))
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.decode_block = decode_block
        self.sample = sample
        self.temperature = temperature
        if kv_layout == "auto":
            kv_layout = "paged" if model.has_pageable else "dense"
        elif kv_layout == "paged" and not model.has_pageable:
            raise ValueError(
                "kv_layout='paged' but no layer of this model is pageable "
                "(full-attention GQA); use 'dense' or 'auto'")
        self.kv_layout = kv_layout
        self._paged = kv_layout == "paged"
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        # paged caches round the gathered length up to whole pages; the
        # rows past max_seq are never unmasked so tokens stay exact
        self._cache_len = (-(-max_seq // page_size) * page_size
                           if self._paged else max_seq)
        self._n_blocks = self._cache_len // page_size if self._paged else 0
        if n_pages is None:
            # dense-equivalent pool (+1 for the reserved null page)
            n_pages = max_batch * self._n_blocks + 1
        self.n_pages = n_pages if self._paged else 0
        if self._paged and self.n_pages < 2:
            raise ValueError("paged layout needs n_pages >= 2 "
                             "(page 0 is the reserved null page)")
        # host-owned allocator. Page 0 is never handed out: block-table
        # entries for unallocated/freed regions stay 0, so garbage writes
        # from frozen slots land on the null page and the position mask
        # keeps its rows out of every attention sum.
        self._free_pages: List[int] = list(range(1, self.n_pages))
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.slot_max_blocks: List[int] = [0] * max_batch
        self._host_bt = np.zeros((max_batch, self._n_blocks), np.int32)
        self._bt_dirty = False
        # double-buffered device-resident param sets: slot j of _buffers
        # holds weight generation _buf_gen[j]; _latest names the buffer new
        # admissions pin to. publish() fills the inactive buffer, so an
        # in-flight request keeps decoding on the exact weights it was
        # admitted under (see _decode_k_dual).
        self._buffers: List[Any] = [params, None]
        self._buf_gen: List[int] = [generation, generation - 1]
        self._latest: int = 0
        self._pending: Optional[Tuple[int, Any]] = None
        if prefill_buckets:
            bs = sorted({int(b) for b in prefill_buckets if b <= max_seq})
            if not bs or bs[-1] != max_seq:
                bs.append(max_seq)    # cap every bucket set at max_seq so
            self.buckets = tuple(bs)  # _bucket always finds a real bucket
        else:
            self.buckets = default_buckets(max_seq)
        self._compiled_buckets: set = set()
        self.state = self._empty_state(
            rng if rng is not None else jax.random.PRNGKey(0))
        if self.mesh is not None:
            self.state = jax.device_put(
                self.state, decode_state_shardings(self.mesh, self.state))
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_len: List[int] = [0] * max_batch   # prompt len per slot
        self.slot_buf: List[int] = [0] * max_batch   # pinned param buffer
        self.waiting: List[Request] = []
        # instrumentation consumed by benchmarks/bench_serve.py and
        # bench_publish.py: the zero-per-token-round-trip claim is
        # `decode_transfers == decode_calls` (one bulk block fetch per
        # fused call) — publishes must not add host syncs
        self.stats: Dict[str, int] = {
            "decode_calls": 0, "decode_transfers": 0, "decode_steps": 0,
            "admissions": 0, "admit_transfers": 0, "prefill_compiles": 0,
            "publishes": 0, "publish_swaps": 0, "publish_superseded": 0,
            "dual_decode_calls": 0, "admit_page_waits": 0, "rejections": 0,
        }
        cache_len = self._cache_len
        self._prefill_fn = jax.jit(
            lambda p, t, L: self.model.prefill(p, t, cache_len=cache_len,
                                               length=L))
        self._admit_fn = jax.jit(self._admit_device, donate_argnums=(0,))
        self._decode_fn = jax.jit(self._decode_k, donate_argnums=(1,))
        self._decode_dual_fn = jax.jit(self._decode_k_dual,
                                       donate_argnums=(2,))

    @property
    def params(self):
        """The latest published parameter set (what new admissions use)."""
        return self._buffers[self._latest]

    @property
    def generation(self) -> int:
        """Weight generation new admissions are pinned to."""
        return self._buf_gen[self._latest]

    # ------------------------------------------------------------------
    # device programs
    # ------------------------------------------------------------------

    def _empty_state(self, rng) -> DecodeState:
        B = self.max_batch
        pool = (self.n_pages, self.page_size) if self._paged else None
        return DecodeState(
            cache=self.model.empty_cache(B, self._cache_len,
                                         page_pool=pool),
            tokens=jnp.zeros((B,), jnp.int32),
            positions=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool),
            remaining=jnp.zeros((B,), jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
            rng=rng,
            block_tables=jnp.zeros((B, self._n_blocks), jnp.int32))

    def _sample(self, logits, key):
        """(B, vocab) logits -> (B,) int32 next tokens."""
        if self.sample == "greedy":
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature,
            axis=-1).astype(jnp.int32)

    def _admit_device(self, state: DecodeState, prefill_cache, first_tok,
                      slot, length, budget, eos_id, active,
                      page_row) -> DecodeState:
        """Scatter a batch=1 prefill cache + fresh slot scalars into
        ``slot``. One compiled program for every admission (prefill caches
        are always padded to ``_cache_len``).

        Dense leaves land via ``dynamic_update_slice`` on the slot's batch
        row. Paged (``p``-layout) pool leaves take the prefill's DENSE
        ``a`` rows, fold them into whole pages, and scatter them to the
        slot's pages named by ``page_row`` — entries past the prompt are 0,
        so their (garbage) pages land on the reserved null page. The host
        block-table mirror is uploaded separately (see ``step``), never
        inside this donated program."""
        src = {path_str(kp): leaf for kp, leaf in
               jax.tree_util.tree_flatten_with_path(prefill_cache)[0]}

        def scatter(path, dst):
            # cache layout (batch dim / page dim) is owned by repro.dist —
            # the same rules cache_shardings uses to place leaves on `data`
            ps = path_str(path)
            pd = page_pool_dim(ps)
            if pd is not None:
                parts = ps.split("/")
                parts[-2] = "a"            # pool leaf <- dense prefill leaf
                leaf = src["/".join(parts)]
                rows = jnp.take(leaf, 0, axis=cache_batch_dim(ps))  # B=1
                M, P = page_row.shape[0], dst.shape[pd + 1]
                rows = rows.reshape(rows.shape[:pd] + (M, P)
                                    + rows.shape[pd + 1:]).astype(dst.dtype)
                if pd == 1:                # stacked-units pool
                    return dst.at[:, page_row].set(rows)
                return dst.at[page_row].set(rows)
            leaf = src[ps]
            bd = cache_batch_dim(ps)
            start = [jnp.int32(0)] * dst.ndim
            start[bd] = slot
            return jax.lax.dynamic_update_slice(
                dst, leaf.astype(dst.dtype), tuple(start))

        cache = jax.tree_util.tree_map_with_path(
            lambda path, dst: scatter(path, dst), state.cache)
        return DecodeState(
            cache=cache,
            tokens=state.tokens.at[slot].set(first_tok),
            positions=state.positions.at[slot].set(length),
            active=state.active.at[slot].set(active),
            remaining=state.remaining.at[slot].set(budget),
            eos=state.eos.at[slot].set(eos_id),
            rng=state.rng,
            block_tables=state.block_tables)

    def _advance(self, st: DecodeState, logits, cache):
        """Shared per-step bookkeeping after the model evaluation(s):
        sample, then mirror the oracle's step — positions advance, budgets
        tick, and a slot stops on budget, EOS, or max_seq-1 truncation,
        all checked AFTER the position increment, like
        ServingEngine._maybe_finish. Finished/free slots freeze so their
        (garbage) rows never index out of bounds. Identical ops in the
        single- and dual-generation programs, so tokens are bitwise
        independent of which program decoded them."""
        max_seq = self.max_seq
        rng, key = jax.random.split(st.rng)
        next_tok = self._sample(logits, key)
        act = st.active
        pos1 = jnp.where(act, st.positions + 1, st.positions)
        rem1 = jnp.where(act, st.remaining - 1, st.remaining)
        hit_eos = (st.eos >= 0) & (next_tok == st.eos)
        done = (rem1 <= 0) | hit_eos | (pos1 >= max_seq - 1)
        return DecodeState(
            cache=cache,
            tokens=jnp.where(act, next_tok, st.tokens),
            positions=pos1,
            active=act & ~done,
            remaining=rem1,
            eos=st.eos,
            rng=rng,
            block_tables=st.block_tables), next_tok

    def _decode_k(self, params, state: DecodeState):
        """K fused decode steps under one jit. Returns (state, (B, K) token
        block) — the block is the ONLY device->host traffic per call."""
        model = self.model

        def body(st: DecodeState, _):
            logits, cache = model.decode(params, st.cache,
                                         st.tokens[:, None], st.positions,
                                         block_tables=st.block_tables)
            return self._advance(st, logits, cache)

        state, toks = jax.lax.scan(body, state, None,
                                   length=self.decode_block)
        return state, toks.T                      # (K, B) -> (B, K)

    def _decode_k_dual(self, params_a, params_b, state: DecodeState, use_b,
                       use_b_pages):
        """K fused decode steps with TWO weight generations resident:
        every slot's logits and cache rows come from the param set its
        request was admitted under — ``jnp.where`` SELECTS between the two
        evaluations (never blends), so an in-flight request's tokens are
        bitwise identical to a single-generation engine pinned at its
        admission weights. Costs two model evaluations per step; the host
        dispatches this program only while generations are actually mixed
        (the old one drains as its requests finish). Still one bulk (B, K)
        transfer per call — publishing adds no host syncs.

        ``use_b_pages`` is the page-pool analogue of the per-slot ``use_b``
        selector: page i belongs to the slot that owns it, so selecting
        per PAGE on pool leaves is exactly selecting per slot (unowned
        pages hold garbage either way)."""
        model = self.model

        def body(st: DecodeState, _):
            logits_a, cache_a = model.decode(params_a, st.cache,
                                             st.tokens[:, None], st.positions,
                                             block_tables=st.block_tables)
            logits_b, cache_b = model.decode(params_b, st.cache,
                                             st.tokens[:, None], st.positions,
                                             block_tables=st.block_tables)
            logits = jnp.where(use_b[:, None], logits_b, logits_a)

            def pick(path, a, b):
                # broadcast the right selector along each cache leaf's
                # batch dim / page dim — the dims owned by repro.dist rules
                ps = path_str(path)
                pd = page_pool_dim(ps)
                sel, d = (use_b_pages, pd) if pd is not None \
                    else (use_b, cache_batch_dim(ps))
                shape = [1] * a.ndim
                shape[d] = a.shape[d]
                return jnp.where(sel.reshape(shape), b, a)

            cache = jax.tree_util.tree_map_with_path(pick, cache_a, cache_b)
            return self._advance(st, logits, cache)

        state, toks = jax.lax.scan(body, state, None,
                                   length=self.decode_block)
        return state, toks.T

    # ------------------------------------------------------------------
    # host scheduler
    # ------------------------------------------------------------------

    def _bucket(self, S: int) -> int:
        for b in self.buckets:
            if b >= S:
                return b
        # unreachable: construction always ends the bucket set at max_seq
        # and submit() rejects prompts longer than that
        raise AssertionError(f"no prefill bucket covers length {S}")

    def _run_prefill(self, bucket: int, padded, length):
        """Dispatch the bucketed prefill, counting the compile the first
        time each bucket's program is traced (warmup or post-warmup)."""
        if bucket not in self._compiled_buckets:
            self._compiled_buckets.add(bucket)
            self.stats["prefill_compiles"] += 1
        return self._prefill_fn(self.params, padded, jnp.int32(length))

    # ---- host page allocator (paged layout only) ----------------------

    def _full_blocks(self, S: int, max_new_tokens: int) -> int:
        """Worst-case pages a request can ever touch (prompt + budget,
        truncated at max_seq) — what admission must reserve."""
        last = min(S + max_new_tokens - 1, self.max_seq - 1)
        return last // self.page_size + 1

    def _reserved_pages(self) -> int:
        """Pages already promised to in-flight requests but not yet
        allocated. Admission keeps ``free >= reserved`` so mid-decode
        growth can never exhaust the pool."""
        return sum(self.slot_max_blocks[i] - len(self.slot_pages[i])
                   for i, r in enumerate(self.slot_req) if r is not None)

    def _alloc_slot_pages(self, slot: int, need: int) -> None:
        pages = self.slot_pages[slot]
        while len(pages) < need:
            if not self._free_pages:
                raise RuntimeError(
                    "page pool exhausted — admission reservation invariant "
                    "violated (this is a bug)")
            pid = self._free_pages.pop()
            self._host_bt[slot, len(pages)] = pid
            pages.append(pid)
            self._bt_dirty = True

    def _release_slot(self, slot: int) -> None:
        self.slot_req[slot] = None
        if self._paged:
            self._free_pages.extend(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self.slot_max_blocks[slot] = 0
            if self._host_bt[slot].any():
                self._host_bt[slot] = 0
                self._bt_dirty = True

    def _ensure_pages(self) -> None:
        """Grow every active slot's block table to cover the rows the next
        fused block can write (host-side, between decode calls)."""
        K, P = self.decode_block, self.page_size
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            # device position the slot's NEXT write lands on
            p0 = self.slot_len[slot] + len(req.generated) - 1
            last = min(p0 + K - 1, self.max_seq - 1)
            # never past the admission-time reservation: a slot that stops
            # mid-block (budget/EOS) freezes at a row max_blocks covers,
            # so rows beyond it are never written while this slot owns it
            self._alloc_slot_pages(
                slot, min(last // P + 1, self.slot_max_blocks[slot]))

    def _push_block_tables(self) -> None:
        if self._bt_dirty:
            self.state = self.state._replace(
                block_tables=jnp.asarray(self._host_bt))  # async, tiny h->d
            self._bt_dirty = False

    def submit(self, request: Request) -> None:
        S = request.prompt.shape[0]
        if S > self.max_seq:
            raise ValueError(
                f"prompt of {S} tokens cannot fit the engine cache "
                f"(max_seq={self.max_seq})")
        if self._paged:
            full = self._full_blocks(S, request.max_new_tokens)
            if full > self.n_pages - 1:
                raise ValueError(
                    f"request needs {full} pages but the pool only has "
                    f"{self.n_pages - 1} allocatable (n_pages={self.n_pages},"
                    f" page_size={self.page_size})")
        request.submit_t = float(self._clock())
        self.waiting.append(request)
        self._admit()

    def _admit_deadline(self, req: Request) -> Optional[float]:
        d = req.deadline_s if req.deadline_s is not None \
            else self.admit_timeout_s
        if d is None:
            return None
        return (req.submit_t or 0.0) + d

    def _shed_expired(self) -> None:
        """Reject waiting requests whose admission deadline has passed —
        bounded head-of-line blocking: a request the pool cannot admit in
        time is shed explicitly (rejected=True) so the queue behind it
        keeps moving and callers never wait forever."""
        if not self.waiting:
            return
        now = float(self._clock())
        kept = []
        for req in self.waiting:
            deadline = self._admit_deadline(req)
            if deadline is not None and now > deadline:
                req.rejected = True
                req.done = True
                self.stats["rejections"] += 1
            else:
                kept.append(req)
        self.waiting = kept

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        # re-derive free slots every iteration: a request that finishes AT
        # admission (budget 1 / instant EOS / truncation) leaves its slot
        # free for the next waiting request in this same pass; a deferred
        # publish is retried each iteration too, so a request admitted
        # after the blocking slot freed picks up the newest generation
        self._apply_pending()
        self._shed_expired()
        while self.waiting:
            self._apply_pending()
            free = self._free_slots()
            if not free:
                return
            full_blocks = 0
            if self._paged:
                # head-of-line page gate: reserve the request's worst-case
                # pages up front, or wait for in-flight requests to free
                # some (FIFO — no later, smaller request jumps the queue)
                head = self.waiting[0]
                full_blocks = self._full_blocks(head.prompt.shape[0],
                                                head.max_new_tokens)
                if (len(self._free_pages) - self._reserved_pages()
                        < full_blocks):
                    self.stats["admit_page_waits"] += 1
                    return
            slot = free[0]
            req = self.waiting.pop(0)
            S = req.prompt.shape[0]
            bucket = self._bucket(S)
            padded = jnp.pad(req.prompt[None, :].astype(jnp.int32),
                             ((0, 0), (0, bucket - S)))
            logits, pc = self._run_prefill(bucket, padded, S)
            if self.sample == "greedy":
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[0]
            else:
                self.state, key = self._split_host_key()
                tok = jax.random.categorical(
                    key, logits.astype(jnp.float32)
                    / self.temperature, axis=-1).astype(jnp.int32)[0]
            t0 = int(tok)                         # one scalar per ADMISSION
            self.stats["admissions"] += 1
            self.stats["admit_transfers"] += 1
            req.generated = [t0]
            req.generation = self.generation      # pinned for its lifetime
            done0 = (req.max_new_tokens <= 1
                     or (req.eos_id is not None and t0 == req.eos_id)
                     or S >= self.max_seq - 1)
            page_row = np.zeros((self._n_blocks,), np.int32)
            if self._paged and not done0:
                # allocate only the PROMPT's pages now (rows 0..S — the
                # prompt plus the first decode write); growth happens
                # lazily in _ensure_pages as the request decodes
                self.slot_max_blocks[slot] = full_blocks
                self._alloc_slot_pages(
                    slot, min(S // self.page_size + 1, full_blocks))
                page_row = self._host_bt[slot].copy()
            self.state = self._admit_fn(
                self.state, pc, tok, jnp.int32(slot), jnp.int32(S),
                jnp.int32(req.max_new_tokens - 1), jnp.int32(
                    -1 if req.eos_id is None else req.eos_id),
                jnp.asarray(not done0), jnp.asarray(page_row))
            if done0:
                req.done = True
            else:
                self.slot_req[slot] = req
                self.slot_len[slot] = S
                self.slot_buf[slot] = self._latest

    def _split_host_key(self):
        rng, key = jax.random.split(self.state.rng)
        return self.state._replace(rng=rng), key

    # ------------------------------------------------------------------
    # live weight publishing
    # ------------------------------------------------------------------

    def publish(self, params,
                generation: Optional[int] = None) -> Optional[bool]:
        """Queue ``params`` as the next weight generation and swap it in as
        soon as the inactive buffer is free of pinned in-flight requests
        (often immediately). In-flight requests keep decoding on their
        admission-time weights; new admissions pick up the new generation.

        Only the newest queued publish survives — if another lands before
        a deferred one applied, the older is superseded (counted in
        ``stats['publish_superseded']``). Returns True when the swap
        happened inside this call, False when deferred (it will apply
        between decode calls once the old generation drains), and None
        when REJECTED as stale (``generation`` not newer than what the
        engine already serves or has queued) — so publishers can tell
        "delivered" (True/False) from "dropped" (None)."""
        base = self._buf_gen[self._latest]
        if self._pending is not None:
            base = max(base, self._pending[0])     # don't collide with a
        gen = base + 1 if generation is None else int(generation)  # queued gen
        if gen <= self._buf_gen[self._latest]:
            return None                           # stale republish
        if self._pending is not None:
            if gen <= self._pending[0]:
                return None
            self.stats["publish_superseded"] += 1
        self.stats["publishes"] += 1
        self._pending = (gen, params)
        return self._apply_pending()

    def _apply_pending(self) -> bool:
        """Swap the pending params into the inactive buffer unless a live
        request still pins it (double-buffering invariant: a buffer is
        only overwritten once no in-flight request can read it)."""
        if self._pending is None:
            return False
        target = 1 - self._latest
        if any(r is not None and self.slot_buf[i] == target
               for i, r in enumerate(self.slot_req)):
            return False                          # deferred: buffer busy
        gen, params = self._pending
        ref = self._buffers[self._latest]

        def place(new, old):
            new = jnp.asarray(new, getattr(old, "dtype", None))
            if new.shape != old.shape:
                raise ValueError(
                    f"published params have leaf shape {new.shape} where "
                    f"the engine expects {old.shape} — generation "
                    f"published from a different model config?")
            return new

        # cast to the resident dtypes/shapes so the compiled decode
        # programs are reused as-is (a publish must never recompile)
        placed = jax.tree_util.tree_map(place, params, ref)
        if self.mesh is not None:
            # re-pin to the serving mesh: the cast above does not carry the
            # resident buffer's sharding over to the new generation
            placed = jax.device_put(placed,
                                    param_shardings(self.mesh, placed))
        self._buffers[target] = placed
        self._buf_gen[target] = gen
        self._latest = target
        self._pending = None
        self.stats["publish_swaps"] += 1
        return True

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def cache_bytes(self) -> int:
        """Device-resident bytes of the whole cache tree (page pool +
        dense leaves + scheduler vectors' cache) — what the paged/int8
        concurrency benchmark holds fixed across layouts."""
        return sum(int(l.nbytes)
                   for l in jax.tree_util.tree_leaves(self.state.cache))

    def step(self) -> None:
        """One fused K-token decode call for all slots, then a single bulk
        host transfer and a host-side replay of the device stop rule.

        The host knows which param buffer every active slot is pinned to
        (its replay mirror), so choosing the single- vs dual-generation
        program needs no device sync: the common case (all slots on one
        generation) runs exactly the pre-publishing program."""
        if self.active == 0:
            return
        if self._paged:
            self._ensure_pages()      # host alloc for the next K writes
            self._push_block_tables()
        bufs = {self.slot_buf[i] for i, r in enumerate(self.slot_req)
                if r is not None}
        if len(bufs) == 1:
            self.state, block = self._decode_fn(
                self._buffers[bufs.pop()], self.state)
        else:
            use_b = jnp.asarray(
                [b == 1 for b in self.slot_buf])       # async, tiny, h->d
            use_b_pages = np.zeros((max(self.n_pages, 1),), bool)
            for i, r in enumerate(self.slot_req):
                if r is not None and self.slot_buf[i] == 1:
                    use_b_pages[self.slot_pages[i]] = True
            self.state, block = self._decode_dual_fn(
                self._buffers[0], self._buffers[1], self.state, use_b,
                jnp.asarray(use_b_pages))
            self.stats["dual_decode_calls"] += 1
        self.stats["decode_calls"] += 1
        self.stats["decode_steps"] += self.decode_block
        block = np.asarray(block)                 # ONE (B, K) transfer
        self.stats["decode_transfers"] += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            for k in range(self.decode_block):
                t = int(block[slot, k])
                req.generated.append(t)
                n = len(req.generated)
                pos_after = self.slot_len[slot] + n - 1
                if (n >= req.max_new_tokens
                        or (req.eos_id is not None and t == req.eos_id)
                        or pos_after >= self.max_seq - 1):
                    req.done = True
                    self._release_slot(slot)      # pages return to the pool
                    break
        self._admit()

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> tokens."""
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.active or self.waiting) and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: r.generated for r in requests}

    # ------------------------------------------------------------------

    def warmup(self, dual: bool = False) -> None:
        """Compile the fixed program set (one prefill per bucket, the
        admission scatter, the fused decode block) before serving.
        ``dual=True`` additionally compiles the two-generation decode
        program, so the first mid-flight publish pays no compile — pass it
        when the engine will receive live weight swaps."""
        dummy = jnp.zeros((1, self.buckets[0]), jnp.int32)
        _, pc = self._run_prefill(self.buckets[0], dummy, 1)
        for b in self.buckets[1:]:
            self._run_prefill(b, jnp.zeros((1, b), jnp.int32), 1)
        st = self._empty_state(jax.random.PRNGKey(0))
        st = self._admit_fn(st, pc, jnp.int32(0), jnp.int32(0),
                            jnp.int32(1), jnp.int32(0), jnp.int32(-1),
                            jnp.asarray(False),
                            jnp.zeros((self._n_blocks,), jnp.int32))
        st, _ = self._decode_fn(self.params, st)
        if dual:
            other = self._buffers[1 - self._latest]
            st, _ = self._decode_dual_fn(
                self.params, other if other is not None else self.params,
                st, jnp.zeros((self.max_batch,), bool),
                jnp.zeros((max(self.n_pages, 1),), bool))
        jax.block_until_ready(st.tokens)
