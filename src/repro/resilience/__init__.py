"""Fault tolerance for the SWAP train→average→publish→serve loop.

Layers (see docs/resilience.md):

  * liveness      — ``repro.dist.heartbeat`` (file beacons → elastic
                    arrivals + live masks);
  * supervision   — ``PhaseSupervisor`` here: bounded retry + backoff
                    around ``run_phase``, NaN/divergence rollback, and
                    dead-worker recovery through the elastic shrink path;
  * integrity     — checksummed checkpoint sidecars + verified fallback
                    (``repro.checkpoint.state``);
  * degradation   — serving admission deadlines + publish retry
                    (``repro.serve``).

Exercised end to end by ``repro.testing.faults`` /
``tests/test_resilience.py``.
"""
from repro.resilience.supervisor import (DivergenceError, PhaseSupervisor,
                                         RecoveryEvent, SupervisedResult,
                                         SupervisorConfig, SupervisorError,
                                         WorkerLostError)

__all__ = [
    "DivergenceError",
    "PhaseSupervisor",
    "RecoveryEvent",
    "SupervisedResult",
    "SupervisorConfig",
    "SupervisorError",
    "WorkerLostError",
]
