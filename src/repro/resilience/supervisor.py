"""Supervised phase execution: bounded retry, divergence rollback, and
dead-worker recovery for ``run_phase`` / ``SWAP.run``.

State machine (per ``PhaseSupervisor.run_phase`` call)::

    RUN ──ok──────────────────────────────▶ DONE
     │
     ├─ guard trips (nonfinite loss/EMA/params, loss above the
     │  configured bar)                       → DivergenceError
     ├─ liveness trips (a current worker's heartbeat went stale,
     │  checked at every chunk boundary)      → WorkerLostError
     ▼
    attempt += 1 ── attempt > max_retries ──▶ FAIL (SupervisorError)
     │
     ▼
    BACKOFF  sleep(backoff_s * factor**(attempt-1))   (injectable sleep)
     ▼
    RESTORE  newest *verified* checkpoint for the tag (else the phase's
             initial state), minus any dead workers — a prefix loss goes
             through the audited ``shrink_worker_axis`` path, a
             mid-ensemble loss through ``take_worker_axis`` — then
             re-placed on the mesh and re-RUN for the remaining steps.

Why chunk boundaries are enough: the phase engine only surfaces state at
compiled-chunk boundaries anyway (docs/training.md), so that is both the
finest granularity at which damage is observable and the coarsest at
which recovery must act. The guard runs BEFORE ``run_phase``'s hooks and
checkpoint cadence for the chunk — a poisoned state is never snapshotted
and never published.

Divergence semantics: a retry replays from the restore point. Transient
faults (the chaos suite's one-shot host-level injections, a flaky host)
pass on replay; a *data-driven* divergence recurs deterministically and
exhausts the retry budget — which is correct: retrying cannot fix a
learning-rate explosion, and the SupervisorError says so.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.state import (checkpoint_workers, list_checkpoints,
                                    load_train_state, state_step,
                                    take_worker_axis, verify_snapshot)
from repro.train.loop import as_hooks
from repro.train.loop import run_phase as _run_phase


class DivergenceError(RuntimeError):
    """Nonfinite or exploding training signal detected at a chunk
    boundary (loss, accuracy EMA, or parameters)."""


class WorkerLostError(RuntimeError):
    """One or more phase-2 workers stopped heartbeating mid-phase."""

    def __init__(self, lost, msg: Optional[str] = None):
        self.lost = sorted(int(w) for w in lost)
        super().__init__(
            msg or f"worker(s) {self.lost} stopped heartbeating")


class SupervisorError(RuntimeError):
    """The retry budget is spent (or no workers survive)."""


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    max_retries: int = 2          # recovery attempts per phase call
    backoff_s: float = 0.0        # sleep before retry k: backoff_s*factor^(k-1)
    backoff_factor: float = 2.0
    max_loss: Optional[float] = None   # divergence bar; None = nonfinite only
    check_params: bool = True     # jitted all-finite sweep over params

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1")


@dataclasses.dataclass(frozen=True)
class RecoveryEvent:
    """One recovery the supervisor performed (surfaced in SWAP results)."""
    kind: str                     # "divergence" | "worker_lost"
    attempt: int                  # 1-based recovery attempt number
    tag: str                      # phase tag being supervised
    error: str                    # the triggering error, stringified
    restored_step: int            # step of the state resumed from
    restored_from: str            # checkpoint path, or "initial state"
    lost_workers: Tuple[int, ...] = ()


class SupervisedResult(NamedTuple):
    """`PhaseResult` plus what supervision did. ``steps``/``train_time``
    accumulate across retries (work discarded by a rollback still
    happened); ``worker`` is the possibly-shrunk worker index array the
    phase finished with."""
    state: Any
    steps: int
    train_time: float
    hook_time: float
    worker: Any
    events: Tuple[RecoveryEvent, ...]


class _Guard:
    """Health checks on the state/metrics a compiled chunk surfaced."""

    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self._finite_fn = None

    def check(self, state, metrics: Dict[str, Any]) -> None:
        loss = metrics.get("loss")
        if loss is not None:
            loss = np.asarray(loss)
            ok = np.isfinite(loss)
            if "skipped" in metrics:
                # dynamic-loss-scale policies legitimately overflow and
                # skip steps; only an overflow the scaler did NOT catch
                # counts as divergence
                ok = ok | (np.asarray(metrics["skipped"]) > 0)
            if not ok.all():
                raise DivergenceError(
                    f"nonfinite loss in chunk ending at step "
                    f"{state_step(state)}")
            if self.cfg.max_loss is not None:
                last = loss[..., -1]
                if (last > self.cfg.max_loss).any():
                    raise DivergenceError(
                        f"loss {float(np.max(last)):.4g} above the "
                        f"divergence bar {self.cfg.max_loss} at step "
                        f"{state_step(state)}")
        if not np.isfinite(np.asarray(state.acc_ema)).all():
            raise DivergenceError(
                f"nonfinite accuracy EMA at step {state_step(state)}")
        if self.cfg.check_params and not self._params_finite(state):
            raise DivergenceError(
                f"nonfinite parameter(s) at step {state_step(state)}")

    def _params_finite(self, state) -> bool:
        if self._finite_fn is None:
            def all_finite(params):
                checks = [jnp.all(jnp.isfinite(leaf))
                          for leaf in jax.tree_util.tree_leaves(params)
                          if jnp.issubdtype(leaf.dtype, jnp.inexact)]
                if not checks:
                    return jnp.asarray(True)
                return jnp.all(jnp.stack(checks))
            # one jitted reduction, one scalar transfer per chunk
            self._finite_fn = jax.jit(all_finite)
        return bool(self._finite_fn(state.bundle["params"]))


class _GuardedRunner:
    """``run_chunk`` proxy: inner chunk → optional fault filter (the chaos
    harness's injection point) → guard. Everything else (loader,
    ensemble, ...) delegates to the wrapped runner."""

    def __init__(self, runner, guard: _Guard,
                 chunk_filter: Optional[Callable] = None):
        self._runner = runner
        self._guard = guard
        self._filter = chunk_filter

    def __getattr__(self, name):
        return getattr(self._runner, name)

    def run_chunk(self, state, worker, n_steps):
        state, metrics = self._runner.run_chunk(state, worker, n_steps)
        if self._filter is not None:
            state, metrics = self._filter(state, metrics)
        self._guard.check(state, metrics)
        return state, metrics


class PhaseSupervisor:
    """Runs a training phase to completion through faults.

    ``monitor`` is an optional ``repro.dist.heartbeat.HeartbeatMonitor``;
    with one attached, every chunk boundary of an ensemble phase checks
    the CURRENT workers' liveness and a stale worker triggers recovery.
    ``sleep`` is injectable so tests assert the backoff schedule without
    real waiting.
    """

    def __init__(self, cfg: Optional[SupervisorConfig] = None, *,
                 monitor=None, sleep: Callable[[float], None] = time.sleep):
        self.cfg = cfg or SupervisorConfig()
        self.monitor = monitor
        self._sleep = sleep

    # ------------------------------------------------------------------

    def run_phase(self, runner, state, worker, *, max_steps: int,
                  tag: str, stop_accuracy=None, chunk_steps=None, log=None,
                  checkpointer=None, checkpoint_meta=None, on_chunk=None,
                  place: Optional[Callable] = None,
                  chunk_filter: Optional[Callable] = None
                  ) -> SupervisedResult:
        """Drop-in for ``repro.train.loop.run_phase`` (same keywords) plus
        ``place`` (re-shard a restored state/worker array onto the mesh,
        e.g. ``SWAP._place_ensemble``) and ``chunk_filter`` (fault
        injection seam, see ``repro.testing.faults``)."""
        ensemble = bool(getattr(runner, "ensemble", False))
        # the compiled chunk donates state buffers (DistConfig.donate_state),
        # so the initial-state restore fallback — and the template an
        # ensemble restore slices — must be HOST copies: the device arrays
        # the caller handed in are dead after the first chunk runs
        _host = lambda x: np.asarray(x) if isinstance(x, jax.Array) else x  # noqa: E731
        init_state = jax.tree_util.tree_map(_host, state)
        init_worker = jax.tree_util.tree_map(_host, worker)
        if ensemble:
            init_ids = [int(x) for x in np.asarray(worker).reshape(-1)]
            ids = list(init_ids)
            # worker-count era → the worker ids a snapshot of that width
            # holds, so a restore of any era can map rows to identities
            # (widths strictly shrink, so eras never collide)
            eras: Dict[int, List[int]] = {len(init_ids): list(init_ids)}
        else:
            init_ids, ids, eras = None, None, {}

        target = state_step(state) + max_steps
        guard = _Guard(self.cfg)
        events: List[RecoveryEvent] = []
        attempt = 0
        steps_total, train_total, hook_total = 0, 0.0, 0.0

        while True:
            hooks = list(as_hooks(on_chunk))
            if ensemble and self.monitor is not None:
                hooks.append(self._liveness_hook(ids))
            guarded = _GuardedRunner(runner, guard, chunk_filter)
            try:
                res = _run_phase(
                    guarded, state, worker,
                    max_steps=max(target - state_step(state), 0),
                    stop_accuracy=stop_accuracy, chunk_steps=chunk_steps,
                    log=log, checkpointer=checkpointer, tag=tag,
                    checkpoint_meta=checkpoint_meta, on_chunk=hooks)
                return SupervisedResult(
                    res.state, steps_total + res.steps,
                    train_total + res.train_time,
                    hook_total + res.hook_time, worker, tuple(events))
            except (DivergenceError, WorkerLostError) as err:
                attempt += 1
                if attempt > self.cfg.max_retries:
                    raise SupervisorError(
                        f"phase {tag!r} failed after "
                        f"{self.cfg.max_retries} recovery attempt(s): "
                        f"{err}") from err
                if isinstance(err, WorkerLostError):
                    ids = [w for w in ids if w not in set(err.lost)]
                    if not ids:
                        raise SupervisorError(
                            f"phase {tag!r}: no workers survive "
                            f"({err})") from err
                self._sleep(self.cfg.backoff_s
                            * self.cfg.backoff_factor ** (attempt - 1))
                state, worker, event = self._restore(
                    err, attempt, tag, checkpointer, ensemble,
                    init_state, init_worker, init_ids, ids, eras, place)
                events.append(event)
                warnings.warn(
                    f"[supervisor] {event.kind} in phase {tag!r} "
                    f"(attempt {attempt}/{self.cfg.max_retries}): {err} — "
                    f"resuming from {event.restored_from} at step "
                    f"{event.restored_step}", RuntimeWarning)

    # ------------------------------------------------------------------

    def _liveness_hook(self, ids: List[int]):
        def hook(state, done):
            dead = self.monitor.dead_among(ids)
            if dead:
                raise WorkerLostError(dead)
        return hook

    def _latest_good(self, checkpointer, tag: str) -> Optional[Dict]:
        if checkpointer is None or not checkpointer.directory:
            return None
        mine = [c for c in list_checkpoints(checkpointer.directory)
                if c["tag"] == tag]
        for c in reversed(mine):
            if verify_snapshot(c["path"], c["meta"]):
                return c
            warnings.warn(
                f"[supervisor] skipping corrupt checkpoint {c['path']}",
                RuntimeWarning)
        return None

    def _restore(self, err, attempt: int, tag: str, checkpointer,
                 ensemble: bool, init_state, init_worker,
                 init_ids: Optional[List[int]], live_ids: Optional[List[int]],
                 eras: Dict[int, List[int]], place: Optional[Callable]):
        entry = self._latest_good(checkpointer, tag)
        if entry is None:
            base_state, restored_from = init_state, "initial state"
            base_ids = list(init_ids) if ensemble else None
        else:
            restored_from = entry["path"]
            if ensemble:
                n_ckpt = checkpoint_workers(entry["meta"]) or len(init_ids)
                base_ids = eras.get(n_ckpt)
                if base_ids is None:
                    raise SupervisorError(
                        f"checkpoint {entry['path']} holds {n_ckpt} "
                        f"workers but no known worker-era matches") from err
                # template sized to the snapshot's era: the initial stacked
                # state minus the workers that era had already lost
                template = init_state if base_ids == init_ids else \
                    take_worker_axis(
                        init_state, [init_ids.index(w) for w in base_ids])
            else:
                base_ids, template = None, init_state
            base_state = load_train_state(entry["path"], template)

        if ensemble:
            keep = [i for i, w in enumerate(base_ids) if w in set(live_ids)]
            if len(keep) != len(base_ids):
                base_state = take_worker_axis(base_state, keep)
            new_ids = [base_ids[i] for i in keep]
            eras[len(new_ids)] = list(new_ids)
            live_ids[:] = new_ids
            worker = jnp.asarray(new_ids, jnp.int32)
        else:
            worker = init_worker

        if place is not None:
            base_state = place(base_state)
            if ensemble:
                worker = place(worker)

        event = RecoveryEvent(
            kind=("worker_lost" if isinstance(err, WorkerLostError)
                  else "divergence"),
            attempt=attempt, tag=tag, error=f"{type(err).__name__}: {err}",
            restored_step=state_step(base_state),
            restored_from=restored_from,
            lost_workers=tuple(getattr(err, "lost", ())))
        return base_state, worker, event
