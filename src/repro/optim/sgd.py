"""SGD with (Nesterov) momentum and decoupled-from-schedule weight decay —
the paper's optimizer (momentum 0.9, wd 5e-4, PyTorch update convention so
the paper's hyper-parameters transfer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def init(params):
    return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params)}


def update(grads, state, params, lr, cfg: OptimizerConfig):
    """Returns (new_params, new_state). L2-style weight decay folded into the
    gradient (the paper's setting), not AdamW-style decoupled decay.
    Gradients arrive pre-cast to the master param dtype (optim.api)."""
    m, wd = cfg.momentum, cfg.weight_decay

    def leaf(g, buf, p):
        d = g + wd * p
        buf = m * buf + d
        step = d + m * buf if cfg.nesterov else buf
        return p - lr * step, buf

    flat = jax.tree_util.tree_map(leaf, grads, state["mu"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu}
