"""Optimizer factory: (init_fn, update_fn) pairs keyed by OptimizerConfig."""
from __future__ import annotations

from repro.configs.base import OptimizerConfig
from repro.optim import adamw, lars, sgd

_MODS = {"sgd": sgd, "lars": lars, "adamw": adamw}


def init_optimizer(cfg: OptimizerConfig):
    """Returns (init_fn(params)->state, update_fn(grads, state, params, lr)
    -> (new_params, new_state))."""
    mod = _MODS.get(cfg.kind)
    if mod is None:
        raise ValueError(f"unknown optimizer {cfg.kind!r}")

    def update_fn(grads, state, params, lr):
        return mod.update(grads, state, params, lr, cfg)

    return mod.init, update_fn
