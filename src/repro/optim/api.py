"""Optimizer factory: (init_fn, update_fn) pairs keyed by OptimizerConfig.

Master-weight contract: parameters (and the optimizer state mirroring
them) live in their master dtype — float32 unless a config says otherwise —
while gradients may arrive in a reduced dtype from the precision pipeline
(``PrecisionPolicy.grad_dtype`` casts them before the data-axis psum).
``update_fn`` promotes every gradient leaf back to its parameter's master
dtype here, once, so the sgd/lars/adamw update math always runs full
precision and SWAP's phase-3 averaging only ever sees master weights.
"""
from __future__ import annotations

import jax

from repro.configs.base import OptimizerConfig
from repro.optim import adamw, lars, sgd

_MODS = {"sgd": sgd, "lars": lars, "adamw": adamw}


def init_optimizer(cfg: OptimizerConfig):
    """Returns (init_fn(params)->state, update_fn(grads, state, params, lr)
    -> (new_params, new_state))."""
    mod = _MODS.get(cfg.kind)
    if mod is None:
        raise ValueError(f"unknown optimizer {cfg.kind!r}")

    def update_fn(grads, state, params, lr):
        grads = jax.tree_util.tree_map(
            lambda g, p: g.astype(p.dtype), grads, params)
        return mod.update(grads, state, params, lr, cfg)

    return mod.init, update_fn
