"""AdamW — beyond-paper optimizer for the LM architectures (the paper's CNN
experiments use SGD; transformer pretraining convention is AdamW)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"mu": z, "nu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def update(grads, state, params, lr, cfg: OptimizerConfig):
    """Gradients arrive pre-cast to the master param dtype (optim.api)."""
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def leaf(g, mu, nu, p):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps) + wd * p
        return p - lr * step, mu, nu

    flat = jax.tree_util.tree_map(leaf, grads, state["mu"], state["nu"], params)
    get = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return get(0), {"mu": get(1), "nu": get(2), "count": count}
