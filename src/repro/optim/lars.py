"""LARS (You et al. 2017) — layer-wise adaptive rate scaling.

The SWAP paper (§6) names LARS as the natural drop-in for phase 1 to push
the large-batch phase further; we provide it as a first-class optimizer.
1-D parameters (norm scales, biases) skip the adaptive scaling, per the
LARS convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def init(params):
    return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params)}


def update(grads, state, params, lr, cfg: OptimizerConfig):
    """Gradients arrive pre-cast to the master param dtype (optim.api)."""
    m, wd, tc = cfg.momentum, cfg.weight_decay, cfg.trust_coefficient

    def leaf(g, buf, p):
        d = g + wd * p
        if p.ndim > 1:
            p_norm = jnp.linalg.norm(p)
            d_norm = jnp.linalg.norm(d)
            trust = jnp.where(
                (p_norm > 0) & (d_norm > 0), tc * p_norm / (d_norm + 1e-12), 1.0)
            d = d * trust
        buf = m * buf + d
        step = d + m * buf if cfg.nesterov else buf
        return p - lr * step, buf

    flat = jax.tree_util.tree_map(leaf, grads, state["mu"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu}
