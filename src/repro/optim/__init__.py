from repro.optim.api import init_optimizer
