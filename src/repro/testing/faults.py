"""Deterministic fault injection for the resilience subsystem.

The chaos harness behind ``tests/test_resilience.py``: every fault the
train→average→publish→serve pipeline must survive is *scripted* here —
worker death at a chosen step, straggler delay, checkpoint byte
corruption, a NaN-loss step, failed publish delivery — and driven by a
``FakeClock`` instead of wall time, so a chaos run is bit-reproducible
and never uses a sleep as synchronization.

Injection seams (all pre-existing production surfaces, no test-only
hooks in the trained path):

  * ``FaultPlan.chunk_filter`` — ``PhaseSupervisor.run_phase``'s
    ``chunk_filter`` argument; poisons the state a compiled chunk
    surfaced, exactly where out-of-band damage would appear.
  * ``FaultPlan.beat_hook`` — a phase-2 ``on_chunk`` hook: beats every
    scripted-alive worker's ``HeartbeatWriter`` and goes silent for a
    killed one, so the ``HeartbeatMonitor`` (sharing the plan's clock)
    declares death from real beacon staleness.
  * ``corrupt_latest_checkpoint`` — flips or truncates bytes of the
    newest snapshot on disk, the out-of-band damage ``verify_snapshot``
    exists to catch.
  * ``FaultPlan.failing_engine`` — a serving-engine stand-in whose
    ``publish`` raises for the first N deliveries, exercising
    ``WeightPublisher``'s retry/skip budget.

NaN injection is one-shot and host-level by design: an in-trace fault
would recur identically on the supervisor's deterministic replay and
(correctly) exhaust the retry budget — the transient-fault story needs
damage that does NOT survive a rollback.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.state import _TAG_ORDER, list_checkpoints


class FakeClock:
    """A callable monotonic clock the test script advances by hand.

    Drop-in for ``time.monotonic`` everywhere a clock is injectable
    (``HeartbeatWriter``/``HeartbeatMonitor``, ``CompiledServingEngine``,
    ``FaultPlan``)."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"a monotonic clock cannot rewind ({dt})")
        self.t += float(dt)
        return self.t


class FaultPlan:
    """A scripted schedule of faults, built fluently::

        plan = (FaultPlan()
                .kill_worker(2, at_step=4)     # beacon goes silent
                .delay_worker(1, by_s=5.0)     # straggler arrival
                .nan_at_step(6)                # one-shot state poison
                .fail_publishes(2))            # first 2 deliveries raise

    All faults are inert until their seam fires, so one plan can carry
    the full chaos scenario for a run.
    """

    def __init__(self, clock: Optional[FakeClock] = None):
        self.clock = clock if clock is not None else FakeClock()
        self.deaths: Dict[int, int] = {}      # worker id -> death step
        self.delays: Dict[int, float] = {}    # worker id -> arrival delay s
        self.nan_step: Optional[int] = None
        self.publish_failures = 0
        self._nan_fired = False
        self._publish_attempts = 0

    # -- builders -------------------------------------------------------

    def kill_worker(self, worker: int, at_step: int) -> "FaultPlan":
        """Worker ``worker`` stops heartbeating once its step reaches
        ``at_step`` (death observed at the next chunk boundary)."""
        self.deaths[int(worker)] = int(at_step)
        return self

    def delay_worker(self, worker: int, by_s: float) -> "FaultPlan":
        """Worker ``worker`` reports ``by_s`` seconds late to phase-3
        averaging (alive, just straggling)."""
        self.delays[int(worker)] = float(by_s)
        return self

    def nan_at_step(self, step: int) -> "FaultPlan":
        """Poison the surfaced parameters with NaN at the first chunk
        boundary whose step is >= ``step`` (once — a transient fault)."""
        self.nan_step = int(step)
        return self

    def fail_publishes(self, n: int = 1) -> "FaultPlan":
        """The first ``n`` publish deliveries to ``failing_engine`` raise."""
        self.publish_failures = int(n)
        return self

    # -- seam: supervisor chunk_filter ----------------------------------

    def chunk_filter(self, state, metrics):
        """``PhaseSupervisor.run_phase(chunk_filter=...)`` seam: one-shot
        NaN poison of every inexact param leaf. Host-level, so the
        supervisor's rollback-and-replay runs clean — exactly a transient
        hardware/numerics fault, not a deterministic divergence."""
        if self.nan_step is None or self._nan_fired:
            return state, metrics
        step = int(np.asarray(state.step).reshape(-1)[0])
        if step < self.nan_step:
            return state, metrics
        self._nan_fired = True

        def poison(leaf):
            a = jnp.asarray(leaf)
            if jnp.issubdtype(a.dtype, jnp.inexact):
                return jnp.full_like(a, jnp.nan)
            return a

        params = jax.tree_util.tree_map(poison, state.bundle["params"])
        return state._replace(bundle=dict(state.bundle,
                                          params=params)), metrics

    # -- seam: phase-2 chunk hook (heartbeats) --------------------------

    def beat_hook(self, writers: Sequence[Any], chunk_wall_s: float = 1.0):
        """An ``on_chunk`` hook that advances the plan's clock by
        ``chunk_wall_s`` per chunk and beats every writer whose worker is
        still scripted alive — a killed worker's beacon simply stops, and
        the monitor (sharing ``self.clock``) times it out for real."""
        def hook(state, done):
            self.clock.advance(chunk_wall_s)
            step = int(np.asarray(state.step).reshape(-1)[0])
            for w in writers:
                death = self.deaths.get(w.worker)
                if death is not None and step >= death:
                    continue
                w.maybe_beat(step=step)
        return hook

    # -- seam: phase-3 simulated arrivals -------------------------------

    def apply_delays(self, arrivals: Sequence[float],
                     worker_ids: Optional[Sequence[int]] = None
                     ) -> List[float]:
        """Add scripted straggler delays to an arrivals list (aligned with
        ``worker_ids``, default 0..n-1) — the simulated-arrival analogue
        of a slow-but-alive worker's stale beacon."""
        ids = (list(range(len(arrivals))) if worker_ids is None
               else [int(w) for w in worker_ids])
        return [a + self.delays.get(w, 0.0) for a, w in zip(arrivals, ids)]

    # -- seam: publish delivery -----------------------------------------

    def failing_engine(self, inner: Optional[Any] = None) -> "FlakyEngine":
        """A serving-engine stand-in bound to this plan's failure budget."""
        return FlakyEngine(self, inner)


class FlakyEngine:
    """Quacks like ``CompiledServingEngine`` for ``WeightPublisher``:
    ``publish`` raises for the plan's first ``publish_failures``
    deliveries, then delegates to ``inner`` (or accepts outright)."""

    def __init__(self, plan: FaultPlan, inner: Optional[Any] = None):
        self.plan = plan
        self.inner = inner
        self.delivered: List[int] = []        # generations that landed

    def publish(self, params, generation: int):
        self.plan._publish_attempts += 1
        if self.plan._publish_attempts <= self.plan.publish_failures:
            raise RuntimeError(
                f"injected publish failure "
                f"{self.plan._publish_attempts}/{self.plan.publish_failures}")
        if self.inner is not None:
            out = self.inner.publish(params, generation=generation)
        else:
            out = True
        if out is not None:
            self.delivered.append(int(generation))
        return out


def corrupt_latest_checkpoint(directory: str, tag: Optional[str] = None,
                              mode: str = "flip") -> str:
    """Damage the newest snapshot on disk (highest resume priority, then
    step — the one ``find_resume_point`` would pick if it verified).

    ``mode="flip"`` xors one mid-file byte (bit rot: the payload still
    unpacks, only the checksum catches it); ``mode="truncate"`` halves the
    file (torn copy: even the legacy payload check catches it). Returns
    the damaged path."""
    ckpts = [c for c in list_checkpoints(directory)
             if tag is None or c["tag"] == tag]
    if not ckpts:
        raise ValueError(f"no checkpoints in {directory!r} to corrupt")
    victim = max(ckpts, key=lambda c: (_TAG_ORDER[c["tag"]], c["step"]))
    path = victim["path"]
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if mode == "flip":
        mid = len(data) // 2
        data[mid] ^= 0xFF
    elif mode == "truncate":
        data = data[:len(data) // 2]
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    with open(path, "wb") as f:
        f.write(bytes(data))
    return path


def truncate_sidecar(path: str, keep_bytes: int = 10) -> str:
    """Truncate a snapshot's JSON sidecar mid-object (the mid-write-kill /
    disk-damage case ``read_meta`` must survive). Returns the sidecar
    path."""
    sidecar = path + ".json"
    with open(sidecar, "rb") as f:
        data = f.read()
    if not os.path.getsize(sidecar) > keep_bytes:
        raise ValueError(f"sidecar {sidecar} too small to truncate")
    with open(sidecar, "wb") as f:
        f.write(data[:keep_bytes])
    return sidecar
