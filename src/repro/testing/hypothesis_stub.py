"""Minimal, dependency-free stand-in for the ``hypothesis`` API the test
suite uses (``given`` / ``settings`` / ``strategies.integers|floats|
sampled_from``).

The CI image does not ship hypothesis and the repo cannot install packages,
so ``tests/conftest.py`` installs this module into ``sys.modules`` **only
when the real library is missing** — with hypothesis installed, the stub is
never imported.

Semantics: each ``@given`` test runs ``max_examples`` times (default 20,
overridable by ``@settings``) with values drawn from a deterministic PRNG
seeded by the test's qualified name, so failures reproduce run-to-run. The
first two examples pin every strategy to its min/max corner, which is where
the seed suite's properties (divisibility, epoch boundaries, W=2 vs W=8)
actually bite. No shrinking — the failing example's kwargs are in the
assertion traceback.
"""
from __future__ import annotations

import inspect
import random
import types
from typing import Any, Callable, List, Sequence


class _Strategy:
    """A strategy is (corner values, random draw)."""

    def __init__(self, corners: Sequence[Any],
                 draw: Callable[[random.Random], Any]):
        self.corners = list(corners)
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy([min_value, max_value],
                     lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
    return _Strategy(
        [min_value, max_value],
        lambda rng: min_value + (max_value - min_value) * rng.random())


def sampled_from(elements: Sequence[Any]) -> _Strategy:
    elements = list(elements)
    return _Strategy([elements[0], elements[-1]],
                     lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> _Strategy:
    return _Strategy([False, True], lambda rng: bool(rng.getrandbits(1)))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    corners = [[elements.corners[0]] * max(min_size, 1),
               [elements.corners[-1]] * max_size]
    return _Strategy(corners, draw)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    """Records max_examples on the decorated (possibly @given-wrapped) fn."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies_by_name):
    """Keyword-style ``@given`` (the only form the suite uses)."""
    def deco(fn):
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in strategies_by_name]

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", None) \
                or getattr(fn, "_stub_max_examples", None) or 20
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            names = sorted(strategies_by_name)
            for i in range(n):
                if i < 2:  # corner examples first: all-min, then all-max
                    drawn = {k: strategies_by_name[k].corners[
                        min(i, len(strategies_by_name[k].corners) - 1)]
                        for k in names}
                else:
                    drawn = {k: strategies_by_name[k].draw(rng)
                             for k in names}
                fn(*args, **kwargs, **drawn)

        # hide the drawn params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


# ``from hypothesis import strategies as st`` resolves this attribute
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.lists = lists

HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                    data_too_large="data_too_large")
