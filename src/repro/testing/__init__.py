"""Test-support utilities (dependency stubs for the hermetic CI image)."""
