"""Mamba-2 SSD intra-chunk kernel in Pallas.

TPU adaptation (vs the Triton SSD kernels in the Mamba-2 release):
  * The O(L^2) intra-chunk block — (C·Bᵀ ∘ decay-mask) @ (dt·x) — is the
    MXU hot spot; it runs as one Pallas program per (batch·head, chunk) with
    chunk length L and head dim P as VMEM-resident tiles (L, P aligned to
    128 by the caller for real-TPU runs).
  * The inter-chunk state recurrence is sequential and tiny
    (nc elements of (P,N) state); it stays in JAX as lax.associative_scan —
    on TPU this is a log-depth tree of elementwise ops, not worth a kernel.
  * No shared-memory banking / warp semantics to port: the decay (segsum)
    matrix is built with broadcasted iota + cumsum inside VMEM.

The kernel emits, per chunk: the intra-chunk output, the chunk-local final
state contribution, and the in-chunk cumulative decay (needed by the
inter-chunk correction applied by the caller).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch


def _ssd_chunk_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                      y_ref, state_ref, cum_ref):
    x = x_ref[0].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0].astype(jnp.float32)          # (L,)
    bm = b_ref[0].astype(jnp.float32)           # (L, N)
    cm = c_ref[0].astype(jnp.float32)           # (L, N)
    a = a_ref[0, 0]                             # scalar A (negative)

    L = x.shape[0]
    dA = dt * a                                 # (L,)
    cum = jnp.cumsum(dA)                        # (L,)

    # segsum decay matrix: seg[i, j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    seg = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (L, L)
    dx = dt[:, None] * x                                            # (L, P)
    y = jax.lax.dot(scores * seg, dx)                               # (L, P)

    # chunk-local final state: sum_j exp(cum_end - cum_j) dt_j x_j ⊗ B_j
    w = jnp.exp(cum[-1] - cum) * dt                                 # (L,)
    state = jax.lax.dot_general(x, bm * w[:, None],
                                (((0,), (0,)), ((), ())))           # (P, N)

    y_ref[0, ...] = y.astype(y_ref.dtype)
    state_ref[0, 0, ...] = state
    cum_ref[0, ...] = cum


def _ssd_chunk_bwd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                          dy_ref, dstate_ref, dcum_ref,
                          dx_ref, ddt_ref, db_ref, dc_ref, da_ref):
    """Intra-chunk SSD backward. Given cotangents of (y_intra, chunk-local
    state, cum), produce (dx, ddt, dB, dC, da) for one (batch·head, chunk)
    tile. All L×L work is MXU matmuls; cum is recomputed in VMEM (cheaper
    than streaming it back from HBM)."""
    x = x_ref[0].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0].astype(jnp.float32)          # (L,)
    bm = b_ref[0].astype(jnp.float32)           # (L, N)
    cm = c_ref[0].astype(jnp.float32)           # (L, N)
    a = a_ref[0, 0]
    dy = dy_ref[0].astype(jnp.float32)          # (L, P)
    dS = dstate_ref[0, 0].astype(jnp.float32)   # (P, N)
    dcum = dcum_ref[0].astype(jnp.float32)      # (L,) from inter-chunk vjp

    L = x.shape[0]
    dA_ = dt * a
    cum = jnp.cumsum(dA_)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = ii >= jj
    seg = jnp.exp(jnp.where(tri, cum[:, None] - cum[None, :], -jnp.inf))
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # C·Bᵀ
    G = scores * seg
    dx_in = dt[:, None] * x                                          # (L,P)

    # --- y_intra = G @ dx_in ---
    dG = jax.lax.dot_general(dy, dx_in, (((1,), (1,)), ((), ())))    # (L,L)
    d_dx = jax.lax.dot_general(G, dy, (((0,), (0,)), ((), ())))      # (L,P)
    dGseg = dG * seg                                                 # masked
    dc = jax.lax.dot(dGseg, bm)                                      # (L,N)
    db = jax.lax.dot_general(dGseg, cm, (((0,), (0,)), ((), ())))    # (L,N)
    E = dG * G                                                       # (L,L)
    dcum = dcum + jnp.sum(E, axis=1) - jnp.sum(E, axis=0)

    # --- state = Σ_j w_j x_j ⊗ B_j, w_j = exp(cum_L - cum_j)·dt_j ---
    wexp = jnp.exp(cum[-1] - cum)                                    # (L,)
    w = wexp * dt
    # dw_j = x_j · (dS @ B_j);  dx_j += w_j (dS @ B_j);  dB_j += w_j (dSᵀ x_j)
    dS_b = jax.lax.dot_general(bm, dS, (((1,), (1,)), ((), ())))     # (L,P)
    dw = jnp.sum(x * dS_b, axis=1)                                   # (L,)
    dx = w[:, None] * dS_b
    db = db + w[:, None] * jax.lax.dot(x, dS)                        # (L,N)
    dcum = dcum - dw * w
    dcum = dcum.at[-1].add(jnp.sum(dw * w))
    ddt = dw * wexp

    # --- dx_in = dt ∘ x ---
    ddt = ddt + jnp.sum(d_dx * x, axis=1)
    dx = dx + dt[:, None] * d_dx

    # --- cum = cumsum(dt·a): reverse-cumsum the dcum ---
    rev = jnp.cumsum(dcum[::-1])[::-1]                               # (L,)
    ddt = ddt + a * rev
    da = jnp.sum(dt * rev)

    dx_ref[0, ...] = dx
    ddt_ref[0, ...] = ddt
    db_ref[0, ...] = db
    dc_ref[0, ...] = dc
    da_ref[0, 0] = da


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas_bwd(x, dt, A, Bm, Cm, dy, dstates, dcum, *,
                         chunk: int = 128, interpret: bool | None = None):
    """Backward of ssd_chunk_pallas. Cotangents: dy (B,S,H,P) for y_intra,
    dstates (B,nc,H,P,N) for chunk-local states, dcum (B,S,H) for cum.
    Returns (dx, ddt, dA, dBm, dCm) with grouped B/C gradients summed over
    the heads sharing each group."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    BH = Bsz * H

    xf = jnp.swapaxes(x, 1, 2).reshape(BH, S, P)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(BH, S)
    bf = jnp.swapaxes(jnp.repeat(Bm, rep, axis=2), 1, 2).reshape(BH, S, N)
    cf = jnp.swapaxes(jnp.repeat(Cm, rep, axis=2), 1, 2).reshape(BH, S, N)
    af = jnp.tile(A.astype(jnp.float32)[None, :], (Bsz, 1)).reshape(BH, 1)
    dyf = jnp.swapaxes(dy.astype(jnp.float32), 1, 2).reshape(BH, S, P)
    dsf = jnp.swapaxes(dstates.astype(jnp.float32), 1, 2).reshape(
        BH, nc, P, N)
    dcf = jnp.swapaxes(dcum.astype(jnp.float32), 1, 2).reshape(BH, S)

    grid = (BH, nc)
    dx, ddt, db, dc, da = pl.pallas_call(
        _ssd_chunk_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, ci)),
        ),
        interpret=interpret,
    )(xf, dtf, bf, cf, af, dyf, dsf, dcf)

    def unflat(t, extra):
        return jnp.swapaxes(t.reshape((Bsz, H) + extra), 1, 2)

    dx_out = unflat(dx, (S, P))
    ddt_out = unflat(ddt, (S,))
    dA_out = jnp.sum(da.reshape(Bsz, H, nc), axis=(0, 2))
    # grouped B/C: sum gradients over the rep heads sharing each group
    db_out = unflat(db, (S, N)).reshape(Bsz, S, G, rep, N).sum(3)
    dc_out = unflat(dc, (S, N)).reshape(Bsz, S, G, rep, N).sum(3)
    return dx_out, ddt_out, dA_out, db_out, dc_out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(x, dt, A, Bm, Cm, *, chunk: int = 128,
                     interpret: bool | None = None):
    """Intra-chunk SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,);
    Bm, Cm: (B,S,G,N) — returns (y_intra (B,S,H,P) f32,
    states (B,nc,H,P,N) f32, cum (B,S,H) f32). S % chunk must be 0.
    ``interpret=None`` resolves per backend (repro.kernels.dispatch)."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    BH = Bsz * H

    # flatten to (B*H, S, ·) batch-head major
    xf = jnp.swapaxes(x, 1, 2).reshape(BH, S, P)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(BH, S)
    bf = jnp.swapaxes(jnp.repeat(Bm, rep, axis=2), 1, 2).reshape(BH, S, N)
    cf = jnp.swapaxes(jnp.repeat(Cm, rep, axis=2), 1, 2).reshape(BH, S, N)
    af = jnp.tile(A.astype(jnp.float32)[None, :], (Bsz, 1)).reshape(BH, 1)

    grid = (BH, nc)
    y, states, cum = pl.pallas_call(
        _ssd_chunk_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
        ),
        interpret=interpret,
    )(xf, dtf, bf, cf, af)

    y = jnp.swapaxes(y.reshape(Bsz, H, S, P), 1, 2)
    states = jnp.swapaxes(states.reshape(Bsz, H, nc, P, N), 1, 2)
    cum = jnp.swapaxes(cum.reshape(Bsz, H, S), 1, 2)
    return y, states, cum
