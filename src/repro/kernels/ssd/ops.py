"""Public SSD scan op (Mamba-2 state-space duality).

``impl="reference"``: chunked pure-jnp SSD — intra-chunk quadratic block plus
log-depth associative scan over chunk states. Same algorithm and memory
behaviour as the kernel path; used for lowering/dry-run and CPU training.

``impl="pallas"``: intra-chunk block from the compiled kernel for the live
backend — Mosaic (kernel.py) on TPU, Triton (kernel_gpu.py) on GPU — with
the inter-chunk correction in JAX; ``impl="mosaic"``/``impl="triton"``
force a lowering (interpreter off its native backend). Backward runs the
matching intra-chunk backward kernel (custom_vjp).

``impl="naive"``: the sequential-recurrence oracle (tests only).

``impl="auto"`` (the config default): backend-resolved — compiled Mosaic on
TPU, compiled Triton on GPU, the chunked reference on CPU
(repro.kernels.dispatch); the Triton path carries the tuning-cache design
point (num_warps/num_stages) unless the caller pins one via ``design``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.ssd import ref as _ref
from repro.kernels.ssd.kernel import ssd_chunk_pallas, ssd_chunk_pallas_bwd
from repro.kernels.ssd.kernel_gpu import ssd_chunk_triton, ssd_chunk_triton_bwd


def _intra_chunk_jnp(x, dt, A, Bm, Cm, chunk):
    """jnp twin of the Pallas intra-chunk kernel.
    Returns (y_intra, states (B,nc,H,P,N), cum (B,S,H)) in f32."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    bf = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    cf = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)

    dA = dtf * A.astype(jnp.float32)                     # (B,nc,L,H)
    cum = jnp.cumsum(dA, axis=2)
    # seg[i,j] = exp(cum_i - cum_j), lower triangular
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangle diff is positive (cum decreasing), and
    # where(mask, exp(diff), 0) would produce 0*inf = NaN in the backward.
    seg = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))

    # scores[i,j] = C_i · B_j  (per group), expanded to heads
    scores = jnp.einsum("bclgn,bcmgn->bclmg", cf, bf)      # (B,nc,L,L,G)
    scores = jnp.repeat(scores, rep, axis=-1)              # (B,nc,L,L,H)
    dx = dtf[..., None] * xf                               # (B,nc,L,H,P)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores * seg, dx)

    # chunk-local final states
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtf             # (B,nc,L,H)
    bw = jnp.repeat(bf, rep, axis=3) * w[..., None]        # (B,nc,L,H,N)
    states = jnp.einsum("bclhp,bclhn->bchpn", xf, bw)      # (B,nc,H,P,N)

    cum_full = cum.reshape(Bsz, S, H)
    return y_intra.reshape(Bsz, S, H, P), states, cum_full


def _inter_chunk(y_intra, states, cum, x, dt, A, Cm, D, chunk, init_state):
    """Combine chunk-local states into the full scan and add corrections."""
    Bsz, S, H, P = y_intra.shape
    G, N = Cm.shape[2], Cm.shape[3]
    rep = H // G
    nc = S // chunk
    cumr = cum.reshape(Bsz, nc, chunk, H)
    chunk_decay = jnp.exp(cumr[:, :, -1, :])               # (B,nc,H)

    # recurrence s_c = a_c * s_{c-1} + b_c  via associative scan over chunks
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2[..., None, None] * b1

    a = chunk_decay
    b = states
    if init_state is not None:
        b = b.at[:, 0].add(a[:, 0][..., None, None] *
                           init_state.astype(jnp.float32))
    a_scan, s_after = jax.lax.associative_scan((combine), (a, b), axis=1)
    # state entering chunk c
    s_in = jnp.concatenate(
        [jnp.zeros_like(s_after[:, :1]) if init_state is None
         else init_state.astype(jnp.float32)[:, None],
         s_after[:, :-1]], axis=1)                         # (B,nc,H,P,N)

    cf = jnp.repeat(Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N),
                    rep, axis=3)                           # (B,nc,L,H,N)
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", cf, s_in)
    y_inter = y_inter * jnp.exp(cumr)[..., None]
    y = y_intra + y_inter.reshape(Bsz, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y, s_after[:, -1]


def _chunked_reference(x, dt, A, Bm, Cm, D, chunk, init_state):
    S = x.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y_intra, states, cum = _intra_chunk_jnp(x, dt, A, Bm, Cm, chunk)
    y, final = _inter_chunk(y_intra, states, cum, x, dt, A, Cm, D, chunk,
                            init_state)
    if pad:
        y = y[:, :S]
        # final state including padded zeros: dt padding = 0 -> decay 1,
        # contribution 0, so the final state is exact.
    return y, final


def _intra_fwd(variant, xp, dtp, A, Bmp, Cmp, c, design, interpret):
    if variant == "triton":
        return ssd_chunk_triton(xp, dtp, A, Bmp, Cmp, chunk=c,
                                design=design, interpret=interpret)
    return ssd_chunk_pallas(xp, dtp, A, Bmp, Cmp, chunk=c,
                            interpret=interpret)


def _intra_bwd(variant, xp, dtp, A, Bmp, Cmp, d_yi, d_st, d_cum, c, design,
               interpret):
    if variant == "triton":
        return ssd_chunk_triton_bwd(xp, dtp, A, Bmp, Cmp, d_yi, d_st,
                                    d_cum, chunk=c, design=design,
                                    interpret=interpret)
    return ssd_chunk_pallas_bwd(xp, dtp, A, Bmp, Cmp, d_yi, d_st, d_cum,
                                chunk=c, interpret=interpret)


# JAX 0.4.37: custom_vjp has no nondiff_argnames; chunk, variant, design and
# interpret (args 7-10, all static/hashable) become positional nondiff
# argnums — bwd takes them first.
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _pallas_ssd(x, dt, A, Bm, Cm, D, init_state, chunk, variant, design,
                interpret):
    S = x.shape[1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y_intra, states, cum = _intra_fwd(variant, x, dt, A, Bm, Cm, c, design,
                                      interpret)
    y, final = _inter_chunk(y_intra, states, cum, x, dt, A, Cm, D, c,
                            init_state)
    if pad:
        y = y[:, :S]
    return y, final


def _pallas_fwd(x, dt, A, Bm, Cm, D, init_state, chunk, variant, design,
                interpret):
    S = x.shape[1]
    c = min(chunk, S)
    pad = (-S) % c
    xp, dtp, Bmp, Cmp = x, dt, Bm, Cm
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y_intra, states, cum = _intra_fwd(variant, xp, dtp, A, Bmp, Cmp, c,
                                      design, interpret)
    y, final = _inter_chunk(y_intra, states, cum, xp, dtp, A, Cmp, D, c,
                            init_state)
    if pad:
        y = y[:, :S]
    return (y, final), (xp, dtp, A, Bmp, Cmp, D, init_state, y_intra,
                        states, cum, pad, c)


def _pallas_bwd(chunk, variant, design, interpret, res, g):
    """True kernel backward: jnp autodiff through the (cheap) inter-chunk
    combine, then the Pallas intra-chunk backward kernel for the O(L²)
    part — no full forward recompute."""
    xp, dtp, A, Bmp, Cmp, D, init_state, y_intra, states, cum, pad, c = res
    dy, dfinal = g
    S = xp.shape[1] - pad
    if pad:
        dy = jnp.pad(dy, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def inter(y_intra, states, cum, x_, Cm_, D_, init_):
        return _inter_chunk(y_intra, states, cum, x_, dtp, A, Cm_, D_, c,
                            init_)
    if init_state is None:
        _, vjp = jax.vjp(lambda yi, st, cu, x_, Cm_, D_: inter(
            yi, st, cu, x_, Cm_, D_, None), y_intra, states, cum, xp, Cmp, D)
        d_yi, d_st, d_cum, dx1, dCm1, dD = vjp((dy, dfinal))
        d_init = None
    else:
        _, vjp = jax.vjp(inter, y_intra, states, cum, xp, Cmp, D, init_state)
        d_yi, d_st, d_cum, dx1, dCm1, dD, d_init = vjp((dy, dfinal))

    dx2, ddt, dA, dBm, dCm2 = _intra_bwd(
        variant, xp, dtp, A, Bmp, Cmp, d_yi, d_st, d_cum, c, design,
        interpret)
    dx = dx1.astype(jnp.float32) + dx2
    dCm = dCm1.astype(jnp.float32) + dCm2
    if pad:
        dx, ddt = dx[:, :S], ddt[:, :S]
        dBm, dCm = dBm[:, :S], dCm[:, :S]
    return (dx.astype(xp.dtype), ddt.astype(dtp.dtype), dA.astype(A.dtype),
            dBm.astype(Bmp.dtype), dCm.astype(Cmp.dtype),
            None if D is None else dD, d_init)


_pallas_ssd.defvjp(_pallas_fwd, _pallas_bwd)


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, init_state=None, chunk: int = 128,
             impl: str = "auto", design=None):
    """Mamba-2 SSD scan. x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,)
    negative; Bm, Cm: (B,S,G,N); D: (H,) or None.
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32).
    ``design`` pins a tuning design point (DesignPoint or 4-tuple);
    default None consults the tuning cache for the resolved backend."""
    d = dispatch.resolve(impl, kernel="ssd",
                         shape=(x.shape[1], x.shape[3]), design=design)
    if d.impl == "naive":
        return _ref.ssd_ref(x, dt, A, Bm, Cm, D, init_state)
    if d.impl == "pallas":
        return _pallas_ssd(x, dt, A, Bm, Cm, D, init_state, chunk,
                           d.variant, d.design, d.interpret)
    return _chunked_reference(x, dt, A, Bm, Cm, D, chunk, init_state)


ssd_decode = _ref.ssd_decode_ref
