"""Pure-jnp oracle for the Mamba-2 SSD scan: the literal sequential recurrence.

    s_t = exp(dt_t * A) * s_{t-1} + dt_t * (x_t ⊗ B_t)
    y_t = (s_t @ C_t) + D * x_t

Slow (O(S) sequential) but unambiguous; ground truth for kernel tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, D=None, init_state=None):
    """x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,G,N) with H % G == 0; D: (H,) or None.
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)   # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(s, inp):
        xt, dtt, bt, ct = inp                      # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt * A)[..., None, None]  # (B,H,1,1)
        s = decay * s + dtt[..., None, None] * xt[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    s, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                     # (B,S,H,P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y, s


def ssd_decode_ref(x, dt, A, Bm, Cm, D, state):
    """Single-token decode. x: (B,H,P); dt: (B,H); Bm,Cm: (B,G,N);
    state: (B,H,P,N). Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    bt = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    ct = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)[..., None, None]
    state = decay * state + dtf[..., None, None] * xf[..., None] * bt[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, ct)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), state
