from repro.kernels.ssd.ops import ssd_scan
