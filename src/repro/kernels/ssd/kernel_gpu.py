"""Mamba-2 SSD intra-chunk kernel, Triton-lowered Pallas GPU variant.

GPU adaptation notes (vs the Mosaic-TPU program in kernel.py):
  * The TPU program was already one independent grid cell per
    (batch-head, chunk) with no cross-step scratch, so the structure ports
    directly; BlockSpecs switch to squeezed ``None`` leading dims and
    ``num_warps``/``num_stages`` become explicit design-point parameters
    (``plgpu.TritonCompilerParams``).
  * ``jnp.cumsum`` / ``.at[].add`` have no reliable Triton lowering on this
    JAX version, so the in-chunk cumulative decay is computed as a masked
    L x L broadcast + row-sum reduction (L is chunk-sized, and the kernel
    already materializes L x L decay/score tiles) and the backward's
    last-position scatter becomes an iota mask.
  * Everything else — the decay (segsum) matrix, the O(L^2) score matmul,
    the chunk-local state outer product — is identical math to the TPU
    kernel; the inter-chunk recurrence stays in JAX (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import triton as plgpu

from repro.kernels import dispatch
from repro.kernels.tuning import DEFAULT_DESIGN, DesignPoint, as_design


def _design(design) -> DesignPoint:
    if design is None:
        return DEFAULT_DESIGN["ssd"]
    return as_design(design)


def _compiler_params(dp: DesignPoint):
    return plgpu.TritonCompilerParams(num_warps=dp.num_warps,
                                      num_stages=dp.num_stages)


def _tri_mats(L):
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    return ii, jj


def _cumsum_masked(x):
    """Inclusive cumulative sum of a (L,) vector via a masked broadcast +
    row-sum — the Triton-lowerable form of jnp.cumsum (tl.dot would need
    every matmul dim >= 16, which a (L, 1) column vector violates)."""
    ii, jj = _tri_mats(x.shape[0])
    return jnp.sum(jnp.where(ii >= jj, x[None, :], 0.0), axis=1)


def _rev_cumsum_masked(x):
    """Reverse (suffix) cumulative sum via the upper-triangular mask."""
    ii, jj = _tri_mats(x.shape[0])
    return jnp.sum(jnp.where(ii <= jj, x[None, :], 0.0), axis=1)


def _ssd_chunk_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                      y_ref, state_ref, cum_ref):
    x = x_ref[...].astype(jnp.float32)          # (L, P)
    dt = dt_ref[...].astype(jnp.float32)        # (L,)
    bm = b_ref[...].astype(jnp.float32)         # (L, N)
    cm = c_ref[...].astype(jnp.float32)         # (L, N)
    a = a_ref[0]                                # scalar A (negative)

    L = x.shape[0]
    dA = dt * a                                 # (L,)
    cum = _cumsum_masked(dA)                       # (L,)

    # segsum decay matrix: seg[i, j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    ii, jj = _tri_mats(L)
    seg = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))

    scores = pl.dot(cm, bm.T)                   # (L, L)
    dx = dt[:, None] * x                        # (L, P)
    y = pl.dot(scores * seg, dx)                # (L, P)

    # chunk-local final state: sum_j exp(cum_end - cum_j) dt_j x_j (x) B_j
    w = jnp.exp(cum[L - 1] - cum) * dt          # (L,)
    state = pl.dot(x.T, bm * w[:, None])        # (P, N)

    y_ref[...] = y.astype(y_ref.dtype)
    state_ref[...] = state
    cum_ref[...] = cum


def _ssd_chunk_bwd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                          dy_ref, dstate_ref, dcum_ref,
                          dx_ref, ddt_ref, db_ref, dc_ref, da_ref):
    """Intra-chunk SSD backward (mirror of the TPU kernel's chain rule);
    cum recomputed in registers, all L x L work on the tensor cores."""
    x = x_ref[...].astype(jnp.float32)          # (L, P)
    dt = dt_ref[...].astype(jnp.float32)        # (L,)
    bm = b_ref[...].astype(jnp.float32)         # (L, N)
    cm = c_ref[...].astype(jnp.float32)         # (L, N)
    a = a_ref[0]
    dy = dy_ref[...].astype(jnp.float32)        # (L, P)
    dS = dstate_ref[...].astype(jnp.float32)    # (P, N)
    dcum = dcum_ref[...].astype(jnp.float32)    # (L,) from inter-chunk vjp

    L = x.shape[0]
    cum = _cumsum_masked(dt * a)
    ii, jj = _tri_mats(L)
    seg = jnp.exp(jnp.where(ii >= jj, cum[:, None] - cum[None, :],
                            -jnp.inf))
    scores = pl.dot(cm, bm.T)
    G = scores * seg
    dx_in = dt[:, None] * x                     # (L, P)

    # --- y_intra = G @ dx_in ---
    dG = pl.dot(dy, dx_in.T)                    # (L, L)
    d_dx = pl.dot(G.T, dy)                      # (L, P)
    dGseg = dG * seg
    dc = pl.dot(dGseg, bm)                      # (L, N)
    db = pl.dot(dGseg.T, cm)                    # (L, N)
    E = dG * G                                  # (L, L)
    dcum = dcum + jnp.sum(E, axis=1) - jnp.sum(E, axis=0)

    # --- state = sum_j w_j x_j (x) B_j, w_j = exp(cum_L - cum_j) dt_j ---
    wexp = jnp.exp(cum[L - 1] - cum)            # (L,)
    w = wexp * dt
    dS_b = pl.dot(bm, dS.T)                     # (L, P)
    dw = jnp.sum(x * dS_b, axis=1)              # (L,)
    dx = w[:, None] * dS_b
    db = db + w[:, None] * pl.dot(x, dS)        # (L, N)
    # dcum_j -= dw_j w_j, with the total re-added at the last position
    # (iota mask — the Triton-lowerable form of .at[-1].add)
    pos = jax.lax.broadcasted_iota(jnp.int32, (L,), 0)
    dcum = dcum - dw * w + jnp.where(pos == L - 1, jnp.sum(dw * w), 0.0)
    ddt = dw * wexp

    # --- dx_in = dt o x ---
    ddt = ddt + jnp.sum(d_dx * x, axis=1)
    dx = dx + dt[:, None] * d_dx

    # --- cum = cumsum(dt a): reverse-cumsum the dcum ---
    rev = _rev_cumsum_masked(dcum)                 # (L,)
    ddt = ddt + a * rev
    da = jnp.sum(dt * rev)

    dx_ref[...] = dx
    ddt_ref[...] = ddt
    db_ref[...] = db
    dc_ref[...] = dc
    da_ref[0] = da


def _flatten(x, dt, A, Bm, Cm):
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    BH = Bsz * H
    xf = jnp.swapaxes(x, 1, 2).reshape(BH, S, P)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(BH, S)
    bf = jnp.swapaxes(jnp.repeat(Bm, rep, axis=2), 1, 2).reshape(BH, S, N)
    cf = jnp.swapaxes(jnp.repeat(Cm, rep, axis=2), 1, 2).reshape(BH, S, N)
    af = jnp.tile(A.astype(jnp.float32)[None, :], (Bsz, 1)).reshape(BH, 1)
    return xf, dtf, bf, cf, af


@functools.partial(jax.jit,
                   static_argnames=("chunk", "design", "interpret"))
def ssd_chunk_triton(x, dt, A, Bm, Cm, *, chunk: int = 128,
                     design: DesignPoint | None = None,
                     interpret: bool | None = None):
    """Intra-chunk SSD, Triton lowering. Same contract as
    ``ssd_chunk_pallas``: x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm:
    (B,S,G,N) — returns (y_intra (B,S,H,P) f32, states (B,nc,H,P,N) f32,
    cum (B,S,H) f32). S % chunk must be 0."""
    if interpret is None:
        interpret = dispatch.current_backend() != "gpu"
    dp = _design(design)
    Bsz, S, H, P = x.shape
    N = Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    BH = Bsz * H
    xf, dtf, bf, cf, af = _flatten(x, dt, A, Bm, Cm)

    grid = (BH, nc)
    y, states, cum = pl.pallas_call(
        _ssd_chunk_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, 1), lambda bh, ci: (bh, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, None, P, N), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
        ),
        compiler_params=_compiler_params(dp),
        interpret=interpret,
    )(xf, dtf, bf, cf, af)

    y = jnp.swapaxes(y.reshape(Bsz, H, S, P), 1, 2)
    states = jnp.swapaxes(states.reshape(Bsz, H, nc, P, N), 1, 2)
    cum = jnp.swapaxes(cum.reshape(Bsz, H, S), 1, 2)
    return y, states, cum


@functools.partial(jax.jit,
                   static_argnames=("chunk", "design", "interpret"))
def ssd_chunk_triton_bwd(x, dt, A, Bm, Cm, dy, dstates, dcum, *,
                         chunk: int = 128,
                         design: DesignPoint | None = None,
                         interpret: bool | None = None):
    """Backward of ssd_chunk_triton; same contract as
    ``ssd_chunk_pallas_bwd`` (grouped B/C gradients summed over the heads
    sharing each group)."""
    if interpret is None:
        interpret = dispatch.current_backend() != "gpu"
    dp = _design(design)
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    BH = Bsz * H
    xf, dtf, bf, cf, af = _flatten(x, dt, A, Bm, Cm)
    dyf = jnp.swapaxes(dy.astype(jnp.float32), 1, 2).reshape(BH, S, P)
    dsf = jnp.swapaxes(dstates.astype(jnp.float32), 1, 2).reshape(
        BH, nc, P, N)
    dcf = jnp.swapaxes(dcum.astype(jnp.float32), 1, 2).reshape(BH, S)

    grid = (BH, nc)
    dx, ddt, db, dc, da = pl.pallas_call(
        _ssd_chunk_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((None, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, None, P, N), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
        ],
        out_specs=(
            pl.BlockSpec((None, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, 1), lambda bh, ci: (bh, ci)),
        ),
        compiler_params=_compiler_params(dp),
        interpret=interpret,
    )(xf, dtf, bf, cf, af, dyf, dsf, dcf)

    def unflat(t, extra):
        return jnp.swapaxes(t.reshape((Bsz, H) + extra), 1, 2)

    dx_out = unflat(dx, (S, P))
    ddt_out = unflat(ddt, (S,))
    dA_out = jnp.sum(da.reshape(Bsz, H, nc), axis=(0, 2))
    db_out = unflat(db, (S, N)).reshape(Bsz, S, G, rep, N).sum(3)
    dc_out = unflat(dc, (S, N)).reshape(Bsz, S, G, rep, N).sum(3)
    return dx_out, ddt_out, dA_out, db_out, dc_out
