"""Blockwise flash attention as a Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * Tiles are BlockSpec-mapped HBM->VMEM blocks, (block_q x D) for Q/O and
    (block_k x D) for K/V, with D padded to a multiple of 128 by the caller
    so the MXU (128x128 systolic array) sees aligned matmul shapes.
  * The KV loop is the minor-most grid dimension; running max / sum / output
    accumulators live in VMEM scratch and persist across KV grid steps
    (TPU grid execution is sequential over the minor dimension, which is
    exactly the flash streaming pattern — no atomics / warp shuffles needed).
  * GQA is handled by the K/V index_map (query head h reads kv head h//G);
    no materialized head repetition in HBM.
  * Causal/sliding-window masking is applied with absolute-position iota
    comparison inside the block. Fully-masked blocks contribute zeros.
  * Backward is flash-attention-2 style: the forward emits LSE; a dQ
    kernel accumulates over KV blocks, and a dK/dV kernel accumulates over
    (query-head-in-group x q-block) pairs via its minor grid dimension —
    GQA's head-group reduction becomes grid scheduling instead of atomics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import dispatch

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, block_q: int,
               block_k: int, q_offset: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len                               # padding mask
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    # fully-masked rows: m_new stays NEG_INF -> p would be exp(0)=1; zero them
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...]
        empty = l == 0.0                               # fully-masked query rows
        l = jnp.where(empty, 1.0, l)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)
        # logsumexp for the backward pass; 0 for empty rows so that
        # exp(s - lse) underflows to 0 there (s stays at NEG_INF)
        lse_ref[0, ...] = jnp.where(empty[:, 0], 0.0,
                                    m_ref[:, 0] + jnp.log(l[:, 0]))


def _layout(q, k, v, block_q, block_k, interpret):
    """Flatten to (B*H, S, D) batch-head major, pad to block/lane multiples."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    Dp = max(128, (D + 127) // 128 * 128) if not interpret else D
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    Sqp = (Sq + block_q - 1) // block_q * block_q
    Skvp = (Skv + block_k - 1) // block_k * block_k

    def prep(x, S, Sp, NH):
        x = jnp.swapaxes(x, 1, 2).reshape(B * NH, S, x.shape[-1])
        return jnp.pad(x, ((0, 0), (0, Sp - S), (0, Dp - x.shape[-1])))

    return (prep(q, Sq, Sqp, H), prep(k, Skv, Skvp, KVH),
            prep(v, Skv, Skvp, KVH), Dp, block_q, block_k, Sqp, Skvp)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D). Returns (B, Sq, H, D)."""
    out, _ = flash_attention_pallas_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_pallas_fwd(q, k, v, *, causal: bool = True,
                               window: int = 0, scale: float | None = None,
                               q_offset: int = 0, block_q: int = 128,
                               block_k: int = 128, interpret: bool | None = None):
    """Forward returning (out (B,Sq,H,D), lse (B,Sq,H) f32) for the
    backward kernels. ``interpret=None`` resolves per backend (compiled on
    TPU, interpreter elsewhere — repro.kernels.dispatch)."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5
    qf, kf, vf, Dp, block_q, block_k, Sqp, Skvp = _layout(
        q, k, v, block_q, block_k, interpret)
    grid = (B * H, Sqp // block_q, Skvp // block_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def lse_map(bh, qi, ki):
        return (bh, qi)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KVH + h // G, ki, 0)

    out, lse = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, q_offset=q_offset, kv_len=Skv),
        out_shape=(
            jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sqp), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dp), q_map),
            pl.BlockSpec((1, block_k, Dp), kv_map),
            pl.BlockSpec((1, block_k, Dp), kv_map),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, Dp), q_map),
            pl.BlockSpec((1, block_q), lse_map),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max
            pltpu.VMEM((block_q, 1), jnp.float32),     # running sum
            pltpu.VMEM((block_q, Dp), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = jnp.swapaxes(out[:, :Sq, :D].reshape(B, H, Sq, D), 1, 2)
    lse = jnp.swapaxes(lse[:, :Sq].reshape(B, H, Sq), 1, 2)
    return out, lse


# ---------------------------------------------------------------------------
# backward kernels (flash-attention-2 style: dQ pass + dK/dV pass)
# ---------------------------------------------------------------------------


def _mask(qi, ki, block_q, block_k, q_offset, q_len, kv_len, causal, window):
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = (kpos < kv_len) & (qpos - q_offset < q_len)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, acc_ref, *, scale, causal, window, block_q,
                      block_k, q_offset, q_len, kv_len):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                 # (block_q,)
    delta = delta_ref[0]                             # (block_q,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    mask = _mask(qi, ki, block_q, block_k, q_offset, q_len, kv_len, causal,
                 window)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                    # (bq, bk)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    acc_ref[...] += jax.lax.dot(ds, k) * scale

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        dq_ref[0, ...] = acc_ref[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                       window, block_q, block_k, q_offset, q_len, kv_len,
                       nq: int):
    ki, gq = pl.program_id(1), pl.program_id(2)
    qi = gq % nq

    @pl.when(gq == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    mask = _mask(qi, ki, block_q, block_k, q_offset, q_len, kv_len, causal,
                 window)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                    # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])                   # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(gq == pl.num_programs(2) - 1)
    def _():
        dk_ref[0, ...] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, ...] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_pallas_bwd(q, k, v, out, lse, do, *, causal: bool = True,
                               window: int = 0, scale: float | None = None,
                               q_offset: int = 0, block_q: int = 128,
                               block_k: int = 128, interpret: bool | None = None):
    """Flash backward. Returns (dq, dk, dv) with the input shapes.
    GQA: dK/dV accumulate over each kv head's G query heads via the grid."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5
    qf, kf, vf, Dp, block_q, block_k, Sqp, Skvp = _layout(
        q, k, v, block_q, block_k, interpret)
    dof = _layout(do, k, v, block_q, block_k, interpret)[0]
    # delta = rowsum(dO * O) — cheap elementwise, computed outside
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), -1)
    deltaf = jnp.pad(jnp.swapaxes(delta, 1, 2).reshape(B * H, Sq),
                     ((0, 0), (0, Sqp - Sq)))
    lsef = jnp.pad(jnp.swapaxes(lse, 1, 2).reshape(B * H, Sq),
                   ((0, 0), (0, Sqp - Sq)))
    nq, nk = Sqp // block_q, Skvp // block_k

    kw = dict(scale=scale, causal=causal, window=window, block_q=block_q,
              block_k=block_k, q_offset=q_offset, q_len=Sq, kv_len=Skv)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def r_map(bh, qi, ki):
        return (bh, qi)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KVH + h // G, ki, 0)

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, **kw),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, Dp), q_map),
            pl.BlockSpec((1, block_k, Dp), kv_map),
            pl.BlockSpec((1, block_k, Dp), kv_map),
            pl.BlockSpec((1, block_q, Dp), q_map),
            pl.BlockSpec((1, block_q), r_map),
            pl.BlockSpec((1, block_q), r_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dp), q_map),
        scratch_shapes=[pltpu.VMEM((block_q, Dp), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    # dK/dV: grid minor dim runs over (g, qi) pairs of this kv head
    def q_map2(bkv, ki, gq):
        b, hkv = bkv // KVH, bkv % KVH
        return (b * H + hkv * G + gq // nq, gq % nq, 0)

    def r_map2(bkv, ki, gq):
        b, hkv = bkv // KVH, bkv % KVH
        return (b * H + hkv * G + gq // nq, gq % nq)

    def kv_map2(bkv, ki, gq):
        return (bkv, ki, 0)

    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, **kw, nq=nq),
        out_shape=(
            jax.ShapeDtypeStruct((B * KVH, Skvp, Dp), k.dtype),
            jax.ShapeDtypeStruct((B * KVH, Skvp, Dp), v.dtype),
        ),
        grid=(B * KVH, nk, G * nq),
        in_specs=[
            pl.BlockSpec((1, block_q, Dp), q_map2),
            pl.BlockSpec((1, block_k, Dp), kv_map2),
            pl.BlockSpec((1, block_k, Dp), kv_map2),
            pl.BlockSpec((1, block_q, Dp), q_map2),
            pl.BlockSpec((1, block_q), r_map2),
            pl.BlockSpec((1, block_q), r_map2),
        ],
        out_specs=(
            pl.BlockSpec((1, block_k, Dp), kv_map2),
            pl.BlockSpec((1, block_k, Dp), kv_map2),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_k, Dp), jnp.float32),
            pltpu.VMEM((block_k, Dp), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    def unflat(x, S, NH):
        return jnp.swapaxes(x[:, :S, :D].reshape(B, NH, S, D), 1, 2)

    return unflat(dq, Sq, H), unflat(dk, Skv, KVH), unflat(dv, Skv, KVH)
