"""Public attention op.

``impl="reference"``: blockwise pure-jnp flash formulation (lax.scan over KV
chunks, online softmax). This is the path used for lowering/dry-run and CPU
execution — it has the same O(S) memory behaviour as the kernel, so compiled
HLO bytes reflect the flash algorithm rather than a materialized QK^T.

``impl="pallas"``: the compiled kernel for the live backend — the Mosaic
program (kernel.py) on TPU, the Triton program (kernel_gpu.py) on GPU;
``impl="mosaic"``/``impl="triton"`` force a specific lowering (interpreter
off its native backend — how CPU CI equivalence-tests both). Gradients via
custom_vjp: forward runs the kernel, backward runs the true flash backward
kernels with the forward's LSE.

``impl="naive"``: the oracle (tests only).

``impl="auto"`` (the config default): backend-resolved — compiled Mosaic on
TPU, compiled Triton on GPU, the blockwise reference on CPU
(repro.kernels.dispatch); the resolved design point (block sizes,
num_warps/num_stages) comes from the persisted tuning cache, or from the
``design`` argument when a caller pins one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import (
    flash_attention_pallas, flash_attention_pallas_bwd,
    flash_attention_pallas_fwd,
)
from repro.kernels.flash_attention.kernel_gpu import (
    flash_attention_triton, flash_attention_triton_bwd,
    flash_attention_triton_fwd,
)
from repro.kernels.tuning import DEFAULT_DESIGN


def _blockwise_reference(q, k, v, *, causal, window, scale, q_offset, chunk):
    """Online-softmax attention, chunked over KV; pure jnp, differentiable."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5
    chunk = min(chunk, Skv)
    # pad Skv to a chunk multiple
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Skv + pad) // chunk

    # keep Q/K/V in their storage dtype (bf16 on TPU) and accumulate the
    # dots in f32 via preferred_element_type — halves the attention HBM
    # traffic vs upcasting inputs to f32 (§Perf iter 4); running stats and
    # the softmax stay f32 for stability.
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KVH, G, D)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KVH, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KVH, D), 1, 0)

    qpos = jnp.arange(Sq) + q_offset

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb,
                       preferred_element_type=jnp.float32)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < Skv
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new > -1e29, p, 0.0)
        alpha = jnp.where(m > -1e29, jnp.exp(m - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, KVH, G, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G, 1), jnp.float32)
    a0 = jnp.zeros((B, Sq, KVH, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


# JAX 0.4.37: custom_vjp has no nondiff_argnames; positional argnums (all
# static/hashable: bools, ints, float-or-None, frozen DesignPoint) express
# the same thing. The bwd signature receives them first, per the argnums
# convention.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pallas_attention(q, k, v, causal, window, scale, q_offset, design,
                      interpret):
    bq, bk = _mosaic_blocks(design)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset,
                                  block_q=bq, block_k=bk,
                                  interpret=interpret)


def _mosaic_blocks(design):
    dflt = DEFAULT_DESIGN["flash_attention"]
    if design is None:
        design = dflt
    return design.block_q or dflt.block_q, design.block_k or dflt.block_k


def _pallas_fwd(q, k, v, causal, window, scale, q_offset, design, interpret):
    bq, bk = _mosaic_blocks(design)
    out, lse = flash_attention_pallas_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=bq, block_k=bk, interpret=interpret)
    return out, (q, k, v, out, lse)


def _pallas_bwd(causal, window, scale, q_offset, design, interpret, res, g):
    # true flash backward (Pallas dQ + dK/dV kernels, LSE from forward)
    q, k, v, out, lse = res
    bq, bk = _mosaic_blocks(design)
    return flash_attention_pallas_bwd(
        q, k, v, out, lse, g, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=bq, block_k=bk, interpret=interpret)


_pallas_attention.defvjp(_pallas_fwd, _pallas_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _triton_attention(q, k, v, causal, window, scale, q_offset, design,
                      interpret):
    return flash_attention_triton(q, k, v, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset,
                                  design=design, interpret=interpret)


def _triton_fwd(q, k, v, causal, window, scale, q_offset, design, interpret):
    out, lse = flash_attention_triton_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, design=design, interpret=interpret)
    return out, (q, k, v, out, lse)


def _triton_bwd(causal, window, scale, q_offset, design, interpret, res, g):
    q, k, v, out, lse = res
    return flash_attention_triton_bwd(
        q, k, v, out, lse, g, causal=causal, window=window, scale=scale,
        q_offset=q_offset, design=design, interpret=interpret)


_triton_attention.defvjp(_triton_fwd, _triton_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int = 0,
                    chunk: int = 512, impl: str = "auto", design=None):
    """GQA flash attention. q: (B,Sq,H,D); k,v: (B,Skv,KVH,D).
    ``design`` pins a tuning design point (DesignPoint or 4-tuple);
    default None consults the tuning cache for the resolved backend."""
    d = dispatch.resolve(impl, kernel="flash_attention",
                         shape=(k.shape[1], q.shape[-1]), design=design)
    if d.impl == "naive":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset)
    if d.impl == "pallas":
        fn = _triton_attention if d.variant == "triton" else _pallas_attention
        return fn(q, k, v, causal, window, scale, q_offset, d.design,
                  d.interpret)
    return _blockwise_reference(q, k, v, causal=causal, window=window,
                                scale=scale, q_offset=q_offset, chunk=chunk)
