"""Pure-jnp oracle for (GQA, causal, sliding-window) attention.

The simplest correct implementation: materializes the full score matrix.
Used as the ground truth for kernel tests; never used for lowering.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None, q_offset: int = 0):
    """Naive attention.

    q: (B, Sq, H, D); k, v: (B, Skv, KVH, D) with H % KVH == 0.
    ``q_offset``: absolute position of q[0] (for decode: Skv - Sq).
    ``window`` > 0 -> sliding-window: key j visible to query i iff
    i - window < j <= i (causal) — gemma3-style local attention.
    Returns (B, Sq, H, D) in q.dtype, accumulation in f32.
    """
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # expand kv heads for GQA
    qf = qf.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)          # (B,KVH,G,Sq,Skv)

    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)

    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)
