"""Blockwise flash attention as a Triton-lowered Pallas GPU kernel.

GPU adaptation notes (vs the Mosaic-TPU program in kernel.py):
  * CUDA thread blocks run CONCURRENTLY, so the TPU trick of carrying the
    online-softmax accumulators in VMEM scratch across sequential minor-grid
    steps does not port. Instead each program owns one (batch-head, q-block)
    tile and streams the KV blocks itself with an in-kernel ``fori_loop``
    over ``pl.ds`` loads — the canonical Triton flash pattern; accumulators
    live in registers.
  * BlockSpecs use ``None`` leading dims (squeezed) and NO pltpu memory
    spaces; K/V map the whole (padded) sequence per program and the loop
    does the tiling, so ``block_q``/``block_k`` are free design-point
    parameters swept by benchmarks/bench_kernels.py.
  * ``num_warps``/``num_stages`` are explicit design-point parameters
    forwarded as ``plgpu.TritonCompilerParams`` (ignored in interpret mode,
    which is how CPU CI equivalence-tests this file).
  * The causal/window structure bounds the KV loop (skips fully-masked
    blocks) and the in-block iota mask handles the boundaries, so padded
    and masked positions contribute exactly zero.
  * Head dim is padded to a power of two >= 16: ``tl.dot`` requires every
    matmul dimension >= 16, and the same padding runs under the
    interpreter so CPU tests exercise the compiled layout.
  * Backward is flash-attention-2 style, mirrored from the TPU kernels: a
    dQ program per (batch-head, q-block) and a dK/dV program per
    (batch-kv-head, kv-block); GQA's head-group reduction runs as a
    statically unrolled loop over the G query heads of the group, with the
    group's Q rows re-laid-out contiguously so every load stays 2-D.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import triton as plgpu

from repro.kernels import dispatch
from repro.kernels.tuning import DEFAULT_DESIGN, DesignPoint, as_design

NEG_INF = -1e30


def _next_pow2(n: int) -> int:
    return 1 << (max(1, n) - 1).bit_length()


def _design(design) -> DesignPoint:
    if design is None:
        return DEFAULT_DESIGN["flash_attention"]
    return as_design(design)


def _compiler_params(dp: DesignPoint):
    return plgpu.TritonCompilerParams(num_warps=dp.num_warps,
                                      num_stages=dp.num_stages)


def _layout(q, k, v, block_q, block_k):
    """Flatten to (B*H, S, D) batch-head major; pad D to pow2 >= 16 and the
    sequences to block multiples (Triton dot dims must be >= 16)."""
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    Dp = max(16, _next_pow2(D))
    block_q = max(16, min(block_q, _next_pow2(Sq)))
    block_k = max(16, min(block_k, _next_pow2(Skv)))
    Sqp = (Sq + block_q - 1) // block_q * block_q
    Skvp = (Skv + block_k - 1) // block_k * block_k

    def prep(x, S, Sp, NH):
        x = jnp.swapaxes(x, 1, 2).reshape(B * NH, S, x.shape[-1])
        return jnp.pad(x, ((0, 0), (0, Sp - S), (0, Dp - x.shape[-1])))

    return (prep(q, Sq, Sqp, H), prep(k, Skv, Skvp, KVH),
            prep(v, Skv, Skvp, KVH), Dp, block_q, block_k, Sqp, Skvp)


def _kv_bounds(qi, *, nk, block_q, block_k, q_offset, causal, window):
    """[lo, hi) kv-block loop bounds for q-block ``qi`` — skip blocks the
    causal/window mask would fully zero (iota masking still guards the
    boundaries inside the loop)."""
    lo = jnp.int32(0)
    hi = jnp.int32(nk)
    if causal:
        hi = jnp.minimum(
            hi, (qi * block_q + block_q + q_offset + block_k - 1) // block_k)
    if window > 0:
        lo = jnp.maximum(
            lo, (qi * block_q + q_offset - window + 1) // block_k)
    return lo, hi


def _fa_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, causal,
                   window, block_q, block_k, q_offset, kv_len):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale               # (bq, D)
    qpos = (qi * block_q + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        kb = pl.load(k_ref, (pl.ds(ki * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        vb = pl.load(v_ref, (pl.ds(ki * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        s = pl.dot(q, kb.T)                                  # (bq, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        alpha = jnp.where(m_prev > NEG_INF / 2,
                          jnp.exp(m_prev - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + pl.dot(p, vb)
        return m_new, l_new, acc

    nk = k_ref.shape[0] // block_k
    lo, hi = _kv_bounds(qi, nk=nk, block_q=block_q, block_k=block_k,
                        q_offset=q_offset, causal=causal, window=window)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, q.shape[1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))

    empty = l == 0.0                                         # fully masked
    l_safe = jnp.where(empty, 1.0, l)
    o_ref[...] = (acc / l_safe).astype(o_ref.dtype)
    lse_ref[...] = jnp.where(empty[:, 0], 0.0,
                             m[:, 0] + jnp.log(l_safe[:, 0]))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "design",
                     "interpret"),
)
def flash_attention_triton_fwd(q, k, v, *, causal: bool = True,
                               window: int = 0, scale: float | None = None,
                               q_offset: int = 0,
                               design: DesignPoint | None = None,
                               interpret: bool | None = None):
    """Forward returning (out (B,Sq,H,D), lse (B,Sq,H) f32). ``design``
    carries (block_q, block_k, num_warps, num_stages); ``interpret=None``
    resolves per backend (compiled on GPU, interpreter elsewhere)."""
    if interpret is None:
        interpret = dispatch.current_backend() != "gpu"
    dp = _design(design)
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5
    qf, kf, vf, Dp, block_q, block_k, Sqp, Skvp = _layout(
        q, k, v, dp.block_q or 128, dp.block_k or 128)
    grid = (B * H, Sqp // block_q)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        b, h = bh // H, bh % H
        return (b * KVH + h // G, 0, 0)

    out, lse = pl.pallas_call(
        functools.partial(
            _fa_fwd_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, q_offset=q_offset,
            kv_len=Skv),
        out_shape=(
            jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sqp), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, Dp), q_map),
            pl.BlockSpec((None, Skvp, Dp), kv_map),
            pl.BlockSpec((None, Skvp, Dp), kv_map),
        ],
        out_specs=(
            pl.BlockSpec((None, block_q, Dp), q_map),
            pl.BlockSpec((None, block_q), lambda bh, qi: (bh, qi)),
        ),
        compiler_params=_compiler_params(dp),
        interpret=interpret,
    )(qf, kf, vf)

    out = jnp.swapaxes(out[:, :Sq, :D].reshape(B, H, Sq, D), 1, 2)
    lse = jnp.swapaxes(lse[:, :Sq].reshape(B, H, Sq), 1, 2)
    return out, lse


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "design",
                     "interpret"),
)
def flash_attention_triton(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None, q_offset: int = 0,
                           design: DesignPoint | None = None,
                           interpret: bool | None = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D). Returns (B, Sq, H, D)."""
    out, _ = flash_attention_triton_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, design=design, interpret=interpret)
    return out


# ---------------------------------------------------------------------------
# backward kernels (flash-attention-2 style: dQ pass + dK/dV pass)
# ---------------------------------------------------------------------------


def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, *, scale, causal, window, block_q, block_k,
                      q_offset, q_len, kv_len):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]                                       # (bq,)
    delta = delta_ref[...]                                   # (bq,)
    qpos = (qi * block_q + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))

    def body(ki, acc):
        kb = pl.load(k_ref, (pl.ds(ki * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        vb = pl.load(v_ref, (pl.ds(ki * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        s = pl.dot(q, kb.T)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (kpos < kv_len) & (qpos - q_offset < q_len)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp_ = pl.dot(do, vb.T)
        ds = p * (dp_ - delta[:, None])
        return acc + pl.dot(ds, kb)

    nk = k_ref.shape[0] // block_k
    lo, hi = _kv_bounds(qi, nk=nk, block_q=block_q, block_k=block_k,
                        q_offset=q_offset, causal=causal, window=window)
    acc = jax.lax.fori_loop(
        lo, hi, body, jnp.zeros((block_q, q.shape[1]), jnp.float32))
    dq_ref[...] = (acc * scale).astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, *, scale, causal, window, block_q,
                       block_k, q_offset, q_len, kv_len, group, sqp):
    """One program per (batch-kv-head, kv-block). Q/dO/LSE/delta arrive with
    the group's G query heads laid out contiguously along the row axis
    ((G*Sqp, D)), so the GQA reduction is a static Python loop over g plus
    a fori_loop over q-blocks — every load a 2-D ``pl.ds`` slice."""
    ki = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)                       # (bk, D)
    v = v_ref[...].astype(jnp.float32)
    kpos = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    nq = sqp // block_q

    lo_q = jnp.int32(0)
    hi_q = jnp.int32(nq)
    if causal:
        lo_q = jnp.maximum(lo_q, (ki * block_k - q_offset) // block_q)
    if window > 0:
        hi_q = jnp.minimum(
            hi_q,
            (ki * block_k + block_k + window - 2 - q_offset) // block_q + 1)

    dk = jnp.zeros((block_k, k.shape[1]), jnp.float32)
    dv = jnp.zeros((block_k, v.shape[1]), jnp.float32)
    for g in range(group):
        def body(qi, carry, g=g):
            dk, dv = carry
            row = g * sqp + qi * block_q
            q = pl.load(q_ref, (pl.ds(row, block_q),
                                slice(None))).astype(jnp.float32) * scale
            do = pl.load(do_ref, (pl.ds(row, block_q),
                                  slice(None))).astype(jnp.float32)
            lse = pl.load(lse_ref, (pl.ds(row, block_q),))
            delta = pl.load(delta_ref, (pl.ds(row, block_q),))
            s = pl.dot(q, k.T)                               # (bq, bk)
            qpos = (qi * block_q + q_offset
                    + jax.lax.broadcasted_iota(
                        jnp.int32, (block_q, block_k), 0))
            mask = (kpos < kv_len) & (qpos - q_offset < q_len)
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse[:, None])                    # (bq, bk)
            dv = dv + pl.dot(p.T, do)
            dp_ = pl.dot(do, v.T)
            ds = p * (dp_ - delta[:, None])
            dk = dk + pl.dot(ds.T, q)
            return dk, dv

        dk, dv = jax.lax.fori_loop(lo_q, hi_q, body, (dk, dv))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "design",
                     "interpret"),
)
def flash_attention_triton_bwd(q, k, v, out, lse, do, *, causal: bool = True,
                               window: int = 0, scale: float | None = None,
                               q_offset: int = 0,
                               design: DesignPoint | None = None,
                               interpret: bool | None = None):
    """Flash backward. Returns (dq, dk, dv) with the input shapes."""
    if interpret is None:
        interpret = dispatch.current_backend() != "gpu"
    dp = _design(design)
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else D ** -0.5
    qf, kf, vf, Dp, block_q, block_k, Sqp, Skvp = _layout(
        q, k, v, dp.block_q or 128, dp.block_k or 128)
    dof = _layout(do, k, v, block_q, block_k)[0]
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), -1)
    deltaf = jnp.pad(jnp.swapaxes(delta, 1, 2).reshape(B * H, Sq),
                     ((0, 0), (0, Sqp - Sq)))
    lsef = jnp.pad(jnp.swapaxes(lse, 1, 2).reshape(B * H, Sq),
                   ((0, 0), (0, Sqp - Sq)))
    nq, nk = Sqp // block_q, Skvp // block_k

    kw = dict(scale=scale, causal=causal, window=window, block_q=block_q,
              block_k=block_k, q_offset=q_offset, q_len=Sq, kv_len=Skv)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        b, h = bh // H, bh % H
        return (b * KVH + h // G, 0, 0)

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, **kw),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
        grid=(B * H, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, Dp), q_map),
            pl.BlockSpec((None, Skvp, Dp), kv_map),
            pl.BlockSpec((None, Skvp, Dp), kv_map),
            pl.BlockSpec((None, block_q, Dp), q_map),
            pl.BlockSpec((None, block_q), lambda bh, qi: (bh, qi)),
            pl.BlockSpec((None, block_q), lambda bh, qi: (bh, qi)),
        ],
        out_specs=pl.BlockSpec((None, block_q, Dp), q_map),
        compiler_params=_compiler_params(dp),
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, deltaf)

    # dK/dV: regroup the G query heads of each kv head contiguously so the
    # kernel addresses them as 2-D row ranges: (B*H, Sqp, D) with rows
    # b*H + hkv*G + g  ==  (B*KVH, G*Sqp, D) row-major.
    def group_rows(x):
        return x.reshape(B * KVH, G * Sqp, *x.shape[2:])

    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, **kw, group=G, sqp=Sqp),
        out_shape=(
            jax.ShapeDtypeStruct((B * KVH, Skvp, Dp), k.dtype),
            jax.ShapeDtypeStruct((B * KVH, Skvp, Dp), v.dtype),
        ),
        grid=(B * KVH, nk),
        in_specs=[
            pl.BlockSpec((None, G * Sqp, Dp), lambda bkv, ki: (bkv, 0, 0)),
            pl.BlockSpec((None, block_k, Dp), lambda bkv, ki: (bkv, ki, 0)),
            pl.BlockSpec((None, block_k, Dp), lambda bkv, ki: (bkv, ki, 0)),
            pl.BlockSpec((None, G * Sqp, Dp), lambda bkv, ki: (bkv, 0, 0)),
            pl.BlockSpec((None, G * Sqp), lambda bkv, ki: (bkv, 0)),
            pl.BlockSpec((None, G * Sqp), lambda bkv, ki: (bkv, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, block_k, Dp), lambda bkv, ki: (bkv, ki, 0)),
            pl.BlockSpec((None, block_k, Dp), lambda bkv, ki: (bkv, ki, 0)),
        ),
        compiler_params=_compiler_params(dp),
        interpret=interpret,
    )(group_rows(qf), kf, vf, group_rows(dof), group_rows(lsef),
      group_rows(deltaf))

    def unflat(x, S, NH):
        return jnp.swapaxes(x[:, :S, :D].reshape(B, NH, S, D), 1, 2)

    return unflat(dq, Sq, H), unflat(dk, Skv, KVH), unflat(dv, Skv, KVH)
