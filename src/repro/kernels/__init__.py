"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package has:
  kernel.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target,
               validated with interpret=True on CPU)
  ops.py     — jit'd public wrapper; dispatches impl in {"reference","pallas"}
  ref.py     — pure-jnp oracle (simplest correct implementation)
"""
