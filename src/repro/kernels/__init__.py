"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package has:
  kernel.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target,
               validated with interpret=True on CPU)
  ops.py     — jit'd public wrapper; dispatches impl in
               {"auto","reference","pallas","naive"}
  ref.py     — pure-jnp oracle (simplest correct implementation)

``repro.kernels.dispatch`` owns the impl/interpret resolution: "auto"
(the config default) runs the compiled kernel on TPU and the jnp
reference elsewhere (the kernels are Mosaic-TPU programs), so
``interpret=True`` is never a hardcoded hot-path default — it is the
off-TPU fallback the resolver picks.
"""
from repro.kernels import dispatch  # noqa: F401
