"""Persisted kernel-autotuning cache: design points per backend x kernel x
shape bucket.

``benchmarks/bench_kernels.py`` sweeps the design-point space (block sizes,
``num_warps``/``num_stages``) per shape bucket on a live backend, scores each
point against the ``benchmarks/roofline.py`` analytical model, and persists
winners here (``tuning_cache.json``, checked in). ``dispatch.resolve``
consults the cache at call time; a miss falls back to the deterministic
``DEFAULT_DESIGN`` so untuned shapes degrade gracefully instead of erroring.

This module is deliberately **stdlib-only** (no jax import): the CI lint job
schema-checks the cache file via ``benchmarks/check_tuning_cache.py`` on a
host with no JAX installed.

Cache schema (``tuning_cache.json``)::

    {
      "version": 1,
      "entries": {
        "<backend>/<kernel>/<bucket>": {
          "block_q": int, "block_k": int,
          "num_warps": int, "num_stages": int
        },
        ...
      }
    }

Keys are ``backend in {cpu,gpu,tpu}`` x ``kernel in KERNELS`` x the kernel's
shape bucket (``shape_bucket``). Per-kernel meaning of the fields:

  kernel           block_q            block_k      num_warps  num_stages
  -----------------------------------------------------------------------
  flash_attention  query tile rows    kv tile rows    yes        yes
  ssd              (unused, 0)        (unused, 0)     yes        yes
  swa_avg          element tile size  (unused, 0)     yes        yes

``block_*`` fields are 0 when a kernel does not use them; 0 also means
"kernel default" when a design point is pinned by hand.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from functools import lru_cache
from typing import Optional, Sequence, Tuple, Union

KERNELS = ("flash_attention", "ssd", "swa_avg")
BACKENDS = ("cpu", "gpu", "tpu")

CACHE_PATH = os.path.join(os.path.dirname(__file__), "tuning_cache.json")

KEY_RE = re.compile(
    r"^(cpu|gpu|tpu)/(flash_attention|ssd|swa_avg)/[a-z0-9_]+$")

_FIELDS = ("block_q", "block_k", "num_warps", "num_stages")
_VALID_WARPS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One point in a kernel's tuning space. Frozen/hashable so it can ride
    through jit static args and ``custom_vjp`` nondiff argnums."""

    block_q: int = 0
    block_k: int = 0
    num_warps: int = 4
    num_stages: int = 2

    def astuple(self) -> Tuple[int, int, int, int]:
        return (self.block_q, self.block_k, self.num_warps, self.num_stages)


# Deterministic fallback when the cache has no entry for a (backend, kernel,
# bucket) key. flash blocks match the Mosaic kernel's long-standing defaults;
# swa_avg's 8192-element tile matches the TPU kernel's (8, 1024) VMEM tile.
DEFAULT_DESIGN = {
    "flash_attention": DesignPoint(block_q=128, block_k=128,
                                   num_warps=4, num_stages=2),
    "ssd": DesignPoint(block_q=0, block_k=0, num_warps=4, num_stages=2),
    "swa_avg": DesignPoint(block_q=8192, block_k=0,
                           num_warps=4, num_stages=2),
}


def as_design(design) -> DesignPoint:
    """Coerce a DesignPoint | 4-tuple | None-fields dict to a DesignPoint."""
    if isinstance(design, DesignPoint):
        return design
    if isinstance(design, dict):
        return DesignPoint(**{k: int(design[k]) for k in _FIELDS})
    if isinstance(design, Sequence):
        vals = tuple(int(v) for v in design)
        if len(vals) != 4:
            raise ValueError(
                f"design point tuple must be (block_q, block_k, num_warps, "
                f"num_stages); got {design!r}")
        return DesignPoint(*vals)
    raise ValueError(f"cannot interpret design point {design!r}")


def _next_pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def shape_bucket(kernel: str,
                 shape: Union[Tuple[int, ...], Sequence[int]]) -> str:
    """Map a call shape to its tuning bucket (power-of-2 size classes).

    Per-kernel shape tuples:
      flash_attention: (kv_len, head_dim)
      ssd:             (seq_len, head_dim P)
      swa_avg:         (numel,)
    """
    if kernel == "flash_attention":
        skv, d = shape
        return f"skv{_next_pow2(skv)}_d{_next_pow2(d)}"
    if kernel == "ssd":
        s, p = shape
        return f"s{_next_pow2(s)}_p{_next_pow2(p)}"
    if kernel == "swa_avg":
        (numel,) = shape
        return f"n{_next_pow2(numel)}"
    raise ValueError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")


@lru_cache(maxsize=None)
def _load(path: str) -> dict:
    if not os.path.exists(path):
        return {"version": 1, "entries": {}}
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "entries" not in data:
        raise ValueError(
            f"malformed tuning cache {path}: expected an object with an "
            f"'entries' key")
    return data


def load_cache(path: Optional[str] = None) -> dict:
    """Load (and memoize) the tuning cache. Missing file -> empty cache."""
    return _load(path or CACHE_PATH)


def clear_cache() -> None:
    """Drop the memoized cache (tests; after --update-cache writes)."""
    _load.cache_clear()


def _entry_errors(key: str, entry) -> list:
    errs = []
    if not KEY_RE.match(key):
        errs.append(f"key {key!r} does not match "
                    f"'backend/kernel/bucket' format ({KEY_RE.pattern})")
    if not isinstance(entry, dict):
        errs.append(f"entry {key!r} is not an object: {entry!r}")
        return errs
    for fld in _FIELDS:
        if fld not in entry:
            errs.append(f"entry {key!r} missing field {fld!r}")
        elif not isinstance(entry[fld], int) or isinstance(entry[fld], bool):
            errs.append(f"entry {key!r} field {fld!r} must be an int, got "
                        f"{entry[fld]!r}")
    extra = set(entry) - set(_FIELDS)
    if extra:
        errs.append(f"entry {key!r} has unknown fields {sorted(extra)}")
    if errs:
        return errs
    if entry["num_warps"] not in _VALID_WARPS:
        errs.append(f"entry {key!r}: num_warps {entry['num_warps']} not in "
                    f"{_VALID_WARPS}")
    if not 1 <= entry["num_stages"] <= 8:
        errs.append(f"entry {key!r}: num_stages {entry['num_stages']} "
                    f"outside [1, 8]")
    for fld in ("block_q", "block_k"):
        v = entry[fld]
        if v < 0 or (v > 0 and v & (v - 1)):
            errs.append(f"entry {key!r}: {fld} {v} must be 0 or a power "
                        f"of 2")
    return errs


def validate_cache(data: dict) -> list:
    """All schema violations in a loaded cache (empty list == valid)."""
    errs = []
    if data.get("version") != 1:
        errs.append(f"unknown cache version {data.get('version')!r}")
    entries = data.get("entries")
    if not isinstance(entries, dict):
        return errs + ["'entries' is not an object"]
    for key, entry in sorted(entries.items()):
        errs.extend(_entry_errors(key, entry))
    return errs


def lookup(backend: str, kernel: str, shape,
           path: Optional[str] = None) -> Optional[DesignPoint]:
    """Cache entry for (backend, kernel, shape's bucket), or None on miss.
    A malformed entry raises a clear ValueError naming the key rather than
    crashing downstream in a jitted trace."""
    entries = load_cache(path).get("entries", {})
    key = f"{backend}/{kernel}/{shape_bucket(kernel, shape)}"
    entry = entries.get(key)
    if entry is None:
        return None
    errs = _entry_errors(key, entry)
    if errs:
        raise ValueError(
            "malformed tuning cache entry (regenerate with "
            "benchmarks/bench_kernels.py --update-cache): "
            + "; ".join(errs))
    return DesignPoint(**{f: entry[f] for f in _FIELDS})


def design_for(backend: str, kernel: str, shape=None,
               path: Optional[str] = None) -> Tuple[DesignPoint, bool]:
    """(design point, cache_hit) — the cached winner for this shape bucket,
    or the kernel's deterministic default on miss / when no shape is given."""
    if shape is not None:
        dp = lookup(backend, kernel, shape, path=path)
        if dp is not None:
            return dp, True
    if kernel not in DEFAULT_DESIGN:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of "
                         f"{KERNELS}")
    return DEFAULT_DESIGN[kernel], False


def update_entries(winners: dict, path: Optional[str] = None) -> str:
    """Merge {key: DesignPoint|dict} winners into the cache file (sorted
    keys, stable formatting) and return the path written."""
    path = path or CACHE_PATH
    data = {"version": 1, "entries": {}}
    if os.path.exists(path):
        data = load_cache(path)
    entries = dict(data.get("entries", {}))
    for key, dp in winners.items():
        dp = as_design(dp)
        entries[key] = {f: getattr(dp, f) for f in _FIELDS}
    out = {"version": 1, "entries": dict(sorted(entries.items()))}
    errs = validate_cache(out)
    if errs:
        raise ValueError("refusing to write invalid tuning cache: "
                         + "; ".join(errs))
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    clear_cache()
    return path
