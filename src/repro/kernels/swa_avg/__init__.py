from repro.kernels.swa_avg.ops import running_average, running_average_tree
