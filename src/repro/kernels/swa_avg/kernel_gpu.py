"""Fused streaming weight-average, Triton-lowered Pallas GPU variant.

GPU adaptation notes (vs the Mosaic-TPU program in kernel.py):
  * The TPU (8, 1024) sublane x lane tile becomes a flat 1-D element tile of
    ``block_q`` elements (the design point's only block parameter; CUDA
    blocks have no sublane structure), one tile per grid cell with
    ``num_warps``/``num_stages`` from the tuning cache.
  * Same fused read-once/write-once contract, and the SAME
    ``avg + (w - avg) / (n + 1)`` divide — never multiply-by-reciprocal —
    so the GPU kernel stays BITWISE equal to the jnp reference and to the
    TPU kernel (the bitwise guarantee phase-2/phase-3 averaging tests pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import triton as plgpu

from repro.kernels import dispatch
from repro.kernels.tuning import DEFAULT_DESIGN, DesignPoint, as_design


def _design(design) -> DesignPoint:
    if design is None:
        return DEFAULT_DESIGN["swa_avg"]
    return as_design(design)


def _avg_kernel(n_ref, avg_ref, w_ref, o_ref):
    n = n_ref[0]
    avg = avg_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # divide, NOT multiply-by-reciprocal — see module docstring (bitwise
    # equality with the jnp reference is load-bearing)
    o_ref[...] = (avg + (w - avg) / (n + 1.0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("design", "interpret"))
def running_average_triton(avg, w, n, *, design: DesignPoint | None = None,
                           interpret: bool | None = None):
    """avg, w: 1-D same-length arrays; n: scalar float count. Same contract
    as ``running_average_pallas``."""
    if interpret is None:
        interpret = dispatch.current_backend() != "gpu"
    dp = _design(design)
    assert avg.ndim == 1 and avg.shape == w.shape
    size = avg.shape[0]
    tile = dp.block_q or DEFAULT_DESIGN["swa_avg"].block_q
    pad = (-size) % tile
    ap = jnp.pad(avg, (0, pad))
    wp = jnp.pad(w, (0, pad))
    nf = jnp.asarray(n, jnp.float32).reshape(1)

    out = pl.pallas_call(
        _avg_kernel,
        out_shape=jax.ShapeDtypeStruct(ap.shape, avg.dtype),
        grid=(ap.shape[0] // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        compiler_params=plgpu.TritonCompilerParams(
            num_warps=dp.num_warps, num_stages=dp.num_stages),
        interpret=interpret,
    )(nf, ap, wp)
    return out[:size]
