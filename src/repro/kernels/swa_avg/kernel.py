"""Fused streaming weight-average Pallas kernel.

Phase 3 of SWAP (and every SWA sample step) folds a full model's weights into
a running mean. On TPU this is a pure HBM-bandwidth op; the kernel streams
(8, 1024)-float32 VMEM tiles (8 sublanes x 8·128 lanes) and fuses the scale +
add so each buffer is read once and written once — no intermediate
(w - avg) materialization in HBM, which is what the naive jnp expression
would allocate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

_LANES = 1024   # 8 * 128, one VREG row of lanes
_SUBS = 8


def _avg_kernel(n_ref, avg_ref, w_ref, o_ref):
    n = n_ref[0, 0]
    avg = avg_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # divide, NOT multiply-by-reciprocal: elementwise ops then match the
    # jnp reference exactly, so kernel and reference stay BITWISE equal
    # (the op is HBM-bandwidth-bound; the VPU divide is free here)
    o_ref[...] = (avg + (w - avg) / (n + 1.0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def running_average_pallas(avg, w, n, *, interpret: bool | None = None):
    """avg, w: 1-D same-length arrays; n: scalar float count.
    ``interpret=None`` resolves per backend (repro.kernels.dispatch)."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    assert avg.ndim == 1 and avg.shape == w.shape
    size = avg.shape[0]
    tile = _SUBS * _LANES
    pad = (-size) % tile
    ap = jnp.pad(avg, (0, pad)).reshape(-1, _SUBS, _LANES)
    wp = jnp.pad(w, (0, pad)).reshape(-1, _SUBS, _LANES)
    nf = jnp.asarray(n, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _avg_kernel,
        out_shape=jax.ShapeDtypeStruct(ap.shape, avg.dtype),
        grid=(ap.shape[0],),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, _SUBS, _LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, _SUBS, _LANES), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _SUBS, _LANES), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(nf, ap, wp)
    return out.reshape(-1)[:size]
