"""Public streaming-average op, scalar-leaf and pytree forms.

``impl="auto"`` (the default) resolves per backend via
repro.kernels.dispatch: the fused Mosaic kernel on TPU, the fused Triton
kernel on GPU, the jnp reference on CPU. All three paths use the same
``avg + (w - avg) / (n + 1)`` divide, so results are BITWISE equal across
impls — the property the averaging tests pin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.swa_avg.kernel import running_average_pallas
from repro.kernels.swa_avg.kernel_gpu import running_average_triton
from repro.kernels.swa_avg.ref import running_average_ref


def running_average(avg, w, n, *, impl: str = "auto", design=None):
    """avg' = avg + (w - avg)/(n+1) for one array. ``design`` pins a tuning
    design point (element tile / num_warps); default None consults the
    tuning cache for the resolved backend."""
    d = dispatch.resolve(impl, kernel="swa_avg", shape=(avg.size,),
                         design=design)
    if d.impl == "pallas":
        if d.variant == "triton":
            flat = running_average_triton(avg.reshape(-1), w.reshape(-1),
                                          jnp.asarray(n, jnp.float32),
                                          design=d.design,
                                          interpret=d.interpret)
        else:
            flat = running_average_pallas(avg.reshape(-1), w.reshape(-1),
                                          jnp.asarray(n, jnp.float32),
                                          interpret=d.interpret)
        return flat.reshape(avg.shape)
    return running_average_ref(avg, w, n)


def running_average_tree(avg_tree, w_tree, n, *, impl: str = "auto"):
    """Streaming average applied leaf-wise to parameter pytrees."""
    return jax.tree_util.tree_map(
        lambda a, w: running_average(a, w, n, impl=impl), avg_tree, w_tree)
