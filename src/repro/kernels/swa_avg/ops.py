"""Public streaming-average op, scalar-leaf and pytree forms."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa_avg.kernel import running_average_pallas
from repro.kernels.swa_avg.ref import running_average_ref


def running_average(avg, w, n, *, impl: str = "reference"):
    """avg' = avg + (w - avg)/(n+1) for one array."""
    if impl == "pallas":
        flat = running_average_pallas(avg.reshape(-1), w.reshape(-1),
                                      jnp.asarray(n, jnp.float32))
        return flat.reshape(avg.shape)
    if impl in ("reference", "naive"):
        return running_average_ref(avg, w, n)
    raise ValueError(f"unknown swa_avg impl {impl!r}")


def running_average_tree(avg_tree, w_tree, n, *, impl: str = "reference"):
    """Streaming average applied leaf-wise to parameter pytrees."""
    return jax.tree_util.tree_map(
        lambda a, w: running_average(a, w, n, impl=impl), avg_tree, w_tree)
