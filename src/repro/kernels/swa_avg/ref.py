"""Oracle for the streaming weight average: avg' = avg + (w - avg) / (n + 1).

This is the phase-3 / SWA hot loop (Izmailov et al. 2018 eq. for the running
mean); exactly equal to the arithmetic mean of the n+1 models seen so far.
"""
import jax.numpy as jnp


def running_average_ref(avg, w, n):
    """avg, w: same-shape arrays; n: scalar count of models already in avg."""
    nf = jnp.asarray(n, jnp.float32)
    return (avg.astype(jnp.float32)
            + (w.astype(jnp.float32) - avg.astype(jnp.float32)) / (nf + 1.0)
            ).astype(avg.dtype)
