"""Backend-aware kernel dispatch: one resolver for every Pallas op.

Before this module, every kernel wrapper hardcoded ``interpret=True`` and
every call site pinned ``impl="reference"`` — correct on the CPU CI host,
but the serving/training hot paths would run interpreter-speed Pallas (or
skip the kernels entirely) on real hardware. ``resolve`` centralizes the
choice:

  requested      backend    -> impl        interpret
  -----------------------------------------------------
  "auto"         tpu        -> "pallas"    False  (compiled kernel)
  "auto"         gpu / cpu  -> "reference" —      (blockwise jnp path)
  "pallas"       tpu        -> "pallas"    False
  "pallas"       gpu / cpu  -> "pallas"    True   (interpreter; tests)
  "reference"    any        -> "reference" —
  "naive"        any        -> "naive"     —      (oracle; tests only)

The repo's kernels are Mosaic-TPU Pallas (pltpu VMEM BlockSpecs/scratch),
so only TPU gets the compiled path; on GPU "auto" stays on the jnp
reference (which XLA fuses well) rather than attempting a TPU-only
lowering. A Triton port would flip that policy here, in one place.

Call sites (models/attention.py, models/mamba2.py, core/averaging.py) pass
the *requested* impl straight from their config (default ``"auto"``); the
three kernel ``ops.py`` wrappers resolve it here, so adding a backend or
flipping the policy is a one-file change. ``interpret_default()`` is the
same rule exposed for code that drives a kernel module directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

KERNEL_IMPLS = ("auto", "pallas", "reference", "naive")


@dataclasses.dataclass(frozen=True)
class KernelDispatch:
    """A resolved kernel choice: concrete impl + Pallas interpret flag."""

    impl: str           # "pallas" | "reference" | "naive"
    interpret: bool     # only meaningful when impl == "pallas"
    backend: str        # backend the decision was made for


def current_backend() -> str:
    """The XLA backend kernels will execute on ("cpu" | "gpu" | "tpu")."""
    return jax.default_backend()


def interpret_default(backend: Optional[str] = None) -> bool:
    """Pallas interpret mode: compiled on TPU, interpreter elsewhere (the
    kernels are Mosaic-TPU programs; CPU has no Pallas lowering and the
    GPU/Triton path cannot lower pltpu memory spaces)."""
    return (backend or current_backend()) != "tpu"


def resolve(requested: str, backend: Optional[str] = None) -> KernelDispatch:
    """Map a requested impl ("auto" | "pallas" | "reference" | "naive") to a
    concrete ``KernelDispatch`` for ``backend`` (default: the live one)."""
    backend = backend or current_backend()
    if requested == "auto":
        impl = "pallas" if backend == "tpu" else "reference"
    elif requested in ("pallas", "reference", "naive"):
        impl = requested
    else:
        raise ValueError(
            f"unknown kernel impl {requested!r}; expected one of "
            f"{KERNEL_IMPLS}")
    return KernelDispatch(impl=impl, interpret=interpret_default(backend),
                          backend=backend)
