"""Backend-aware kernel dispatch: one resolver for every Pallas op.

Before this module, every kernel wrapper hardcoded ``interpret=True`` and
every call site pinned ``impl="reference"`` — correct on the CPU CI host,
but the serving/training hot paths would run interpreter-speed Pallas (or
skip the kernels entirely) on real hardware. ``resolve`` centralizes the
choice. Each kernel package now carries TWO compiled lowerings — the
Mosaic-TPU program (``kernel.py``: pltpu VMEM BlockSpecs/scratch, grid-
carried accumulators) and the Triton-lowered GPU program (``kernel_gpu.py``:
squeezed GPU BlockSpecs, in-kernel ``fori_loop`` reductions,
``num_warps``/``num_stages`` compiler params) — so "auto" means a compiled
kernel on both accelerator backends:

  requested      backend    -> impl        variant    interpret
  ----------------------------------------------------------------
  "auto"         tpu        -> "pallas"    "mosaic"   False (compiled)
  "auto"         gpu        -> "pallas"    "triton"   False (compiled)
  "auto"         cpu        -> "reference" —          —     (jnp path)
  "pallas"       tpu        -> "pallas"    "mosaic"   False
  "pallas"       gpu        -> "pallas"    "triton"   False
  "pallas"       cpu        -> "pallas"    "mosaic"   True  (interpreter)
  "mosaic"       any        -> "pallas"    "mosaic"   backend != tpu
  "triton"       any        -> "pallas"    "triton"   backend != gpu
  "reference"    any        -> "reference" —          —
  "naive"        any        -> "naive"     —          —     (oracle; tests)

"mosaic"/"triton" force a specific lowering (interpreter when the live
backend cannot compile it) — this is how CPU CI equivalence-tests the GPU
variants. When the resolved impl is "pallas", ``resolve`` also consults the
persisted tuning cache (``repro.kernels.tuning``, keyed by backend x kernel
x shape bucket) and carries the winning design point — block sizes,
``num_warps``, ``num_stages`` — into the dispatch; a miss falls back to the
kernel's deterministic ``DEFAULT_DESIGN`` so untuned shapes degrade
gracefully. ``benchmarks/bench_kernels.py`` regenerates the cache. See
docs/kernels.md for the full table, design-point spaces, and cache schema.

Call sites (models/attention.py, models/mamba2.py, core/averaging.py) pass
the *requested* impl straight from their config (default ``"auto"``); the
three kernel ``ops.py`` wrappers resolve it here, so adding a backend or
flipping the policy is a one-file change. ``interpret_default()`` is the
same rule exposed for code that drives a kernel module directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels import tuning
from repro.kernels.tuning import DesignPoint  # noqa: F401  (re-export)

KERNEL_IMPLS = ("auto", "pallas", "mosaic", "triton", "reference", "naive")


@dataclasses.dataclass(frozen=True)
class KernelDispatch:
    """A resolved kernel choice: concrete impl + lowering variant +
    Pallas interpret flag + tuned design point."""

    impl: str           # "pallas" | "reference" | "naive"
    interpret: bool     # only meaningful when impl == "pallas"
    backend: str        # backend the decision was made for
    variant: Optional[str] = None    # "mosaic" | "triton" when impl=="pallas"
    design: Optional[DesignPoint] = None  # tuned/pinned point (pallas only)
    cache_hit: bool = False          # design came from the tuning cache


def current_backend() -> str:
    """The XLA backend kernels will execute on ("cpu" | "gpu" | "tpu")."""
    return jax.default_backend()


def validate_impl(requested: str, where: str = "impl") -> str:
    """Raise a clear ValueError (listing KERNEL_IMPLS) for a typo'd impl
    string — at config-construction time, not deep inside a jitted trace."""
    if requested not in KERNEL_IMPLS:
        raise ValueError(
            f"unknown kernel impl {requested!r} for {where}; expected one "
            f"of {KERNEL_IMPLS}")
    return requested


def interpret_default(backend: Optional[str] = None) -> bool:
    """Pallas interpret mode for the MOSAIC kernels: compiled on TPU,
    interpreter elsewhere (CPU has no Pallas lowering and the GPU/Triton
    path cannot lower pltpu memory spaces)."""
    return (backend or current_backend()) != "tpu"


_NATIVE_VARIANT = {"tpu": "mosaic", "gpu": "triton"}


def resolve(requested: str, backend: Optional[str] = None,
            kernel: Optional[str] = None, shape=None,
            design=None) -> KernelDispatch:
    """Map a requested impl (one of ``KERNEL_IMPLS``) to a concrete
    ``KernelDispatch`` for ``backend`` (default: the live one).

    ``kernel`` ("flash_attention" | "ssd" | "swa_avg") plus ``shape`` (the
    kernel's bucket tuple, see ``tuning.shape_bucket``) enable the tuning-
    cache lookup; ``design`` (DesignPoint or 4-tuple) pins an explicit
    design point, bypassing the cache — the config-surface hook tests use.
    """
    backend = backend or current_backend()
    validate_impl(requested)
    variant: Optional[str] = None
    if requested == "auto":
        variant = _NATIVE_VARIANT.get(backend)
        impl = "pallas" if variant else "reference"
    elif requested == "pallas":
        impl = "pallas"
        # off-accelerator, forced "pallas" keeps its historical meaning:
        # interpret the Mosaic program (the TPU-kernel tests rely on it)
        variant = _NATIVE_VARIANT.get(backend, "mosaic")
    elif requested in ("mosaic", "triton"):
        impl, variant = "pallas", requested
    else:
        impl = requested

    if variant == "triton":
        interpret = backend != "gpu"
    else:
        interpret = interpret_default(backend)

    dp, hit = None, False
    if impl == "pallas" and kernel is not None:
        if design is not None:
            dp = tuning.as_design(design)
        else:
            dp, hit = tuning.design_for(backend, kernel, shape)
    return KernelDispatch(impl=impl, interpret=interpret, backend=backend,
                          variant=variant, design=dp, cache_hit=hit)
