"""Train-time image augmentation (the paper trains with cutout + standard
CIFAR augmentation; that stochasticity is what lets small-batch SGD walk out
of the sharp large-batch solution in phase 2).

For the synthetic GMM task the distribution-consistent analog is fresh
additive noise around the stored sample (same label, perturbed input) plus
cutout. Applied deterministically from the loader-provided ``aug_seed``
(a pure function of (seed, worker, step)), so phase-2 workers see different
augmentations of the same finite dataset."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def augment_images(images, seed, *, noise: float = 1.5, cutout: int = 4):
    """images: (B, H, W, C) f32; seed: int32 scalar."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    k_noise, k_cx, k_cy = jax.random.split(key, 3)
    B, H, W, C = images.shape
    out = images + noise * jax.random.normal(k_noise, images.shape)
    if cutout > 0:
        cx = jax.random.randint(k_cx, (B,), 0, H - cutout + 1)
        cy = jax.random.randint(k_cy, (B,), 0, W - cutout + 1)
        ii = jnp.arange(H)[None, :, None]
        jj = jnp.arange(W)[None, None, :]
        mask = ((ii >= cx[:, None, None]) & (ii < cx[:, None, None] + cutout)
                & (jj >= cy[:, None, None]) & (jj < cy[:, None, None] + cutout))
        out = jnp.where(mask[..., None], 0.0, out)
    return out
