from repro.data.pipeline import Loader, make_gmm_images, make_markov_lm
