"""Deterministic synthetic data pipelines.

The container is offline, so the paper's datasets are replaced with two
synthetic tasks that preserve the properties the paper's claims depend on:

  * a FINITE training set (so a train/test generalization gap exists and
    large-batch training can plateau at worse test accuracy),
  * per-worker independent data ORDER in SWAP phase 2 ("each worker performs
    training using all the data, but sampling in different random order"),
  * exact reproducibility from a seed (epoch permutations are a pure
    function of (seed, worker, epoch)).

Tasks:
  * Markov-chain language modelling — next-token prediction of a fixed
    random low-entropy transition matrix; train sequences are a finite
    sample, test sequences are fresh draws from the same chain.
  * Gaussian-mixture images — n_classes cluster means in (H, W, 3) image
    space + per-sample noise; the CNN+BN paper-faithful model trains on it.
"""
from __future__ import annotations

import warnings
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# dataset builders
# ---------------------------------------------------------------------------


def make_markov_lm(seed: int, vocab: int = 64, n_train: int = 2048,
                   n_test: int = 512, seq_len: int = 64,
                   temperature: float = 0.35) -> Dict[str, np.ndarray]:
    """Finite LM dataset from a fixed random Markov chain. Lower temperature
    -> lower-entropy chain -> higher attainable accuracy."""
    key = jax.random.PRNGKey(seed)
    k_mat, k_train, k_test = jax.random.split(key, 3)
    logits = jax.random.normal(k_mat, (vocab, vocab)) / temperature

    def sample(key, n):
        k0, kseq = jax.random.split(key)
        first = jax.random.randint(k0, (n,), 0, vocab)

        def step(tok, k):
            nxt = jax.random.categorical(k, logits[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(kseq, seq_len)
        _, seqs = jax.lax.scan(step, first, keys)
        return jnp.concatenate([first[:, None], seqs.T], axis=1)  # (n, S+1)

    train = np.asarray(sample(k_train, n_train))
    test = np.asarray(sample(k_test, n_test))
    return {
        "train_tokens": train[:, :-1], "train_labels": train[:, 1:],
        "test_tokens": test[:, :-1], "test_labels": test[:, 1:],
        "transition_logits": np.asarray(logits),
    }


def make_gmm_images(seed: int, n_classes: int = 10, image_size: int = 16,
                    n_train: int = 4096, n_test: int = 1024,
                    noise: float = 1.5) -> Dict[str, np.ndarray]:
    """Gaussian-mixture image classification. `noise` controls task
    difficulty (and therefore the size of the generalization gap)."""
    key = jax.random.PRNGKey(seed)
    k_means, k_train, k_test, k_ltr, k_lte = jax.random.split(key, 5)
    shape = (image_size, image_size, 3)
    means = jax.random.normal(k_means, (n_classes,) + shape)

    def sample(kimg, klab, n):
        labels = jax.random.randint(klab, (n,), 0, n_classes)
        imgs = means[labels] + noise * jax.random.normal(kimg, (n,) + shape)
        return imgs, labels

    tr_x, tr_y = sample(k_train, k_ltr, n_train)
    te_x, te_y = sample(k_test, k_lte, n_test)
    return {
        "train_images": np.asarray(tr_x), "train_labels": np.asarray(tr_y),
        "test_images": np.asarray(te_x), "test_labels": np.asarray(te_y),
    }


# ---------------------------------------------------------------------------
# loader with per-(worker, epoch) permutations
# ---------------------------------------------------------------------------


class Loader:
    """Epoch-permuted batches over a finite dataset.

    ``batch(step, worker)`` is a pure function of (seed, worker, epoch):
    each worker walks the full dataset in its own random order — exactly the
    phase-2 sampling model of the paper. The same loader with worker=0
    serves phase 1 (all workers consume the same global batch, sharded).

    ``shard=(index, count)`` is the per-host data sharding used by
    multi-host launches (``repro.dist.DistConfig`` drives it from
    ``process_id``/``num_processes``): every host computes the SAME global
    epoch permutation (it is a pure function of the seed, so no host
    communication is needed), but each host materializes only its
    ``batch_size // count`` rows of every global batch — host ``i`` takes
    the ``i``-th contiguous slice of the permuted batch window. The shards
    are disjoint and their union is exactly the unsharded batch, in
    permutation order (asserted in tests/test_data_pipeline.py), so a
    sharded multi-host step consumes the same global batch as a
    single-host run. ``steps_per_epoch`` and the augmentation seed stay
    GLOBAL (identical on every host) — sharding changes which rows a host
    holds, never the schedule.
    """

    def __init__(self, arrays: Dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, shard: "tuple[int, int] | None" = None):
        self.arrays = {k: jnp.asarray(v) for k, v in arrays.items()}
        sizes = {v.shape[0] for v in arrays.values()}
        if len(sizes) != 1:
            raise ValueError(
                f"all arrays must share the leading dim, got sizes {sizes}")
        self.n = sizes.pop()
        if batch_size > self.n:
            raise ValueError(
                f"batch_size {batch_size} exceeds dataset size {self.n}")
        if shard is not None:
            index, count = shard
            if not (0 <= index < count):
                raise ValueError(f"shard index {index} out of range for "
                                 f"count {count}")
            if batch_size % count != 0:
                raise ValueError(
                    f"batch_size {batch_size} is not divisible by the "
                    f"shard count {count} — every host must hold an equal "
                    f"slice of each global batch")
        self.shard = shard
        self.batch_size = batch_size
        self.seed = seed
        self.steps_per_epoch = self.n // batch_size
        # epoch walks cover steps_per_epoch * batch_size samples; the
        # remainder never enters ANY epoch (every permutation is truncated
        # at the same offset). Surface it instead of dropping silently —
        # BN-recompute passes and eval loops must know their coverage.
        self.dropped_per_epoch = self.n % batch_size
        if self.dropped_per_epoch:
            warnings.warn(
                f"Loader drops {self.dropped_per_epoch} of {self.n} samples "
                f"every epoch ({batch_size=} does not divide the dataset); "
                f"each epoch covers only steps_per_epoch*batch_size = "
                f"{self.steps_per_epoch * batch_size} samples",
                stacklevel=2)

    def _perm(self, worker, epoch):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), worker), epoch)
        return jax.random.permutation(key, self.n)

    def batch_in_trace(self, step, worker=0) -> Dict[str, jnp.ndarray]:
        """Device-resident batch gather, traceable under jit/vmap/scan.

        ``step`` and ``worker`` may be traced int32 scalars: the epoch
        permutation, the slice offset and the augmentation seed are all pure
        jnp functions of (seed, worker, epoch, step), and the dataset arrays
        live on device from construction — so the phase-2 engine can gather
        each worker's batch *inside* the vmapped/scanned train step with no
        host -> device transfer per step.
        """
        epoch = step // self.steps_per_epoch
        offset = (step % self.steps_per_epoch) * self.batch_size
        local = self.batch_size
        if self.shard is not None:
            # host i's contiguous slice of the globally-permuted batch
            # window; the permutation itself is seed-pure, so every host
            # agrees on it without communicating
            index, count = self.shard
            local = self.batch_size // count
            offset = offset + index * local
        perm = self._perm(worker, epoch)
        idx = jax.lax.dynamic_slice_in_dim(perm, offset, local)
        out = {k: v[idx] for k, v in self.arrays.items()}
        # deterministic augmentation seed per (seed, worker, step); training
        # losses that augment (CNN) consume it, others ignore it. Computed in
        # uint32 so it traces; ((A%M)+B%M)%M == (A+B)%M keeps it equal to the
        # exact-integer host arithmetic it replaced.
        m = jnp.uint32(2**31 - 1)
        base = jnp.uint32((self.seed * 1000003) % (2**31 - 1))
        rest = (jnp.asarray(worker, jnp.uint32) * jnp.uint32(9176)
                + jnp.asarray(step, jnp.uint32)) % m
        out["aug_seed"] = ((base + rest) % m).astype(jnp.int32)
        return out

    def batch(self, step, worker: int = 0) -> Dict[str, jnp.ndarray]:
        """Host-driven alias of ``batch_in_trace`` (same pure function)."""
        return self.batch_in_trace(step, worker)

    def epoch_of(self, step) -> int:
        return step // self.steps_per_epoch
