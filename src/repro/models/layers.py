"""Shared layer primitives: inits, norms, RoPE (incl. M-RoPE), MLP, embeds."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape: Tuple[int, ...], fan_in: int | None = None,
               dtype=jnp.float32):
    """Truncated-normal with 1/sqrt(fan_in) scale (LeCun normal)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# mixed-precision matmul helper
# ---------------------------------------------------------------------------


def mdot(x, w, dtype):
    """Matmul with explicit compute dtype (params stay f32 in HBM)."""
    return jnp.matmul(x.astype(dtype), w.astype(dtype))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(key, d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


def gated_rmsnorm(x, z, scale, eps: float = 1e-6):
    """Mamba-2 RMSNormGated: norm(x * silu(z)) * scale."""
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies for the half-dim."""
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def rope_cos_sin(positions, head_dim: int, theta: float,
                 mrope_sections: Tuple[int, ...] = ()):
    """positions: (..., S) int for standard rope, or (..., 3, S) for M-RoPE.
    Returns (cos, sin) with shape (..., S, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    if mrope_sections:
        # positions (..., 3, S): section i of the half-dim uses component i
        assert positions.shape[-2] == len(mrope_sections)
        parts = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            ang = positions[..., i, :, None].astype(jnp.float32) * inv[off:off + sec]
            parts.append(ang)
            off += sec
        ang = jnp.concatenate(parts, axis=-1)            # (..., S, half)
    else:
        ang = positions[..., :, None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2).
    Llama-style rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    """Whisper-style fixed sinusoidal embeddings. positions: (S,) or (B,S)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff)),
        "wg": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model), fan_in=d_ff),
    }


def apply_mlp(params, x, act: str, dtype):
    h = mdot(x, params["wi"], dtype)
    g = mdot(x, params["wg"], dtype)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return mdot(h * g, params["wo"], dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int):
    return {"table": embed_init(key, (vocab, d_model))}


def embed_tokens(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def lm_head(params, h, dtype):
    """h @ table^T when tied; separate head otherwise (callers pick)."""
    return mdot(h, params["w"], dtype)
