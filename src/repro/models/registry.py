"""Build a model (or the CNN) from a ModelConfig."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.model import Model


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "cnn":
        raise ValueError(
            "cnn family uses repro.models.cnn functional API, not Model")
    return Model(cfg)
