"""Model assembly for all assigned architectures.

Layers are grouped into *pattern units* so heterogeneous stacks lower as a
single ``lax.scan`` over stacked parameters:

  dense/moe/vlm : unit = 1 layer  (gemma3: unit = 5 local + 1 global)
  ssm           : unit = 1 mamba layer
  hybrid        : unit = shared_attn_every mamba layers, with the ONE shared
                  attention block (zamba2) applied before each unit
  audio         : separate encoder / decoder stacks (whisper)

Remaining layers (n_layers % unit_len) form an unrolled tail. Within a unit
the per-position layer kind (local/global window, moe, mamba) is static
Python, so a unit body is trace-time specialized; across units everything is
structurally identical, which keeps compiled HLO size O(unit) instead of
O(n_layers) — essential for the 94-layer MoE dry-run at 512 devices.

Caches are dicts keyed by position-in-unit (string), stacked across units on
the leading axis, so sliding-window layers can hold (window)-sized caches
next to full-length global caches in the same scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical_constraint
from repro.models import attention as attn, mamba2, moe
from repro.models.layers import (
    apply_mlp, apply_norm, dense_init, embed_init, init_mlp, init_norm, mdot,
    sinusoidal_embedding,
)


@dataclasses.dataclass(frozen=True)
class LayerKind:
    block: str          # "attn" | "mamba"
    window: int = 0     # sliding window for attn (0 = full)
    use_moe: bool = False
    cross: bool = False  # adds cross-attention (whisper decoder)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


class Model:
    """Functional model: init/apply/prefill/decode."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.unit_kinds, self.n_units, self.tail_kinds = self._plan(cfg)
        self.use_rope = cfg.family != "audio"

    # ------------------------------------------------------------------
    # layer plan
    # ------------------------------------------------------------------

    @staticmethod
    def _plan(cfg: ModelConfig) -> Tuple[List[LayerKind], int, List[LayerKind]]:
        if cfg.family == "ssm":
            unit = [LayerKind("mamba")]
        elif cfg.family == "hybrid":
            unit = [LayerKind("mamba")] * cfg.shared_attn_every
        elif cfg.family == "audio":
            unit = [LayerKind("attn", cross=True)]
        elif cfg.local_global_pattern != (0, 0):
            loc, glob = cfg.local_global_pattern
            unit = ([LayerKind("attn", window=cfg.sliding_window)] * loc
                    + [LayerKind("attn")] * glob)
        else:
            unit = [LayerKind("attn", window=cfg.sliding_window,
                              use_moe=cfg.family == "moe")]
        if cfg.family == "moe":
            unit = [dataclasses.replace(k, use_moe=True) for k in unit]
        n_units, rem = divmod(cfg.n_layers, len(unit))
        tail = unit[:rem]
        return unit, n_units, tail

    # ------------------------------------------------------------------
    # paged-cache capability
    # ------------------------------------------------------------------

    def pageable(self, kind: LayerKind) -> bool:
        """Whether a layer's KV cache can live in a paged page pool:
        full-attention GQA self-attention only. Sliding-window caches are
        already O(window), SSM states are O(1), and MLA/cross caches keep
        their dense layout behind this capability gate."""
        return (kind.block == "attn" and kind.window == 0
                and not kind.cross and self.cfg.attention == "gqa")

    @property
    def has_pageable(self) -> bool:
        """True if any layer can use a paged KV pool (the serving engine's
        ``kv_layout="auto"`` resolves to paged exactly then)."""
        kinds = list(self.unit_kinds) + list(self.tail_kinds)
        if self.cfg.family == "hybrid":
            kinds.append(LayerKind("attn"))
        return any(self.pageable(k) for k in kinds)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def _init_block(self, key, kind: LayerKind):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        if kind.block == "mamba":
            return {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
                    "mamba": mamba2.init_mamba(ks[1], cfg)}
        p = {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
             "ln2": init_norm(ks[1], cfg.d_model, cfg.norm)}
        if cfg.attention == "mla":
            p["attn"] = attn.init_mla(ks[2], cfg)
        else:
            p["attn"] = attn.init_gqa(ks[2], cfg)
        if kind.cross and cfg.is_encoder_decoder:
            p["lnx"] = init_norm(ks[3], cfg.d_model, cfg.norm)
            p["xattn"] = attn.init_gqa(ks[4], cfg, cross=True)
        if kind.use_moe:
            p["moe"] = moe.init_moe(ks[5], cfg)
        else:
            p["mlp"] = init_mlp(ks[5], cfg.d_model, cfg.d_ff)
        return p

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": {"table": embed_init(keys[0], (cfg.vocab_size, cfg.d_model))},
            "final_norm": init_norm(keys[1], cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["head"] = {
                "w": dense_init(keys[2], (cfg.d_model, cfg.vocab_size))}

        unit_len = len(self.unit_kinds)
        n_stack = self.n_units * unit_len
        if n_stack:
            bkeys = jax.random.split(keys[3], n_stack).reshape(
                self.n_units, unit_len, 2)
            # vmap twice: over units and positions. Kinds vary by position,
            # so vmap over units only, python-loop positions.
            per_pos = []
            for i, kind in enumerate(self.unit_kinds):
                per_pos.append(jax.vmap(
                    lambda k, kind=kind: self._init_block(k, kind))(bkeys[:, i]))
            # per_pos[i] leaves: (n_units, ...); stack positions on axis 1
            params["blocks"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=1), *per_pos)
        if self.tail_kinds:
            tkeys = jax.random.split(keys[4], len(self.tail_kinds) * 2)
            params["tail"] = _tree_stack([
                self._init_block(jax.random.fold_in(keys[4], i), kind)
                for i, kind in enumerate(self.tail_kinds)])
        if cfg.family == "hybrid":
            params["shared"] = self._init_block(keys[5], LayerKind("attn"))
        if cfg.is_encoder_decoder:
            ekeys = jax.random.split(keys[6], cfg.n_encoder_layers)
            params["encoder"] = {
                "blocks": jax.vmap(
                    lambda k: self._init_block(k, LayerKind("attn")))(ekeys),
                "norm": init_norm(keys[7], cfg.d_model, cfg.norm),
            }
        return params

    # ------------------------------------------------------------------
    # block execution (full sequence: train / prefill)
    # ------------------------------------------------------------------

    def _block_full(self, p, h, kind: LayerKind, positions, mode: str,
                    enc_out=None, init_cache=None, length=None):
        """Returns (h, cache_or_None, aux_loss). ``length``: real-token
        count for right-padded prefill buckets (see Model.prefill)."""
        cfg = self.cfg
        # keep the residual stream batch-sharded at every block boundary so
        # GSPMD resolves weight matmuls by gathering weights, not by
        # partial-summing activations across the data axis (§Perf iter 3)
        if cfg.constrain_residual:
            h = logical_constraint(h, ("batch", None, None))
        aux = jnp.zeros((), jnp.float32)
        cache = {}
        if kind.block == "mamba":
            x = apply_norm(p["ln1"], h, cfg.norm, cfg.norm_eps)
            if mode == "prefill":
                y, mc = mamba2.mamba_forward(p["mamba"], x, cfg,
                                             return_cache=True, length=length)
                cache["m"] = mc
            else:
                y = mamba2.mamba_forward(p["mamba"], x, cfg)
            return h + y, cache, aux

        x = apply_norm(p["ln1"], h, cfg.norm, cfg.norm_eps)
        causal = not (cfg.family == "audio" and mode == "encode")
        if cfg.attention == "mla":
            if mode == "prefill":
                y, ac = attn.mla_forward(p["attn"], x, cfg,
                                         positions=positions, return_cache=True)
                cache["a"] = ac
            else:
                y = attn.mla_forward(p["attn"], x, cfg, positions=positions)
        else:
            pos = positions if self.use_rope else None
            if mode == "prefill":
                y, ac = attn.gqa_forward(
                    p["attn"], x, cfg, positions=pos, window=kind.window,
                    causal=causal, return_cache=True, length=length)
                cache["a"] = ac
            else:
                y = attn.gqa_forward(p["attn"], x, cfg, positions=pos,
                                     window=kind.window, causal=causal)
        h = h + y
        if kind.cross and enc_out is not None:
            x = apply_norm(p["lnx"], h, cfg.norm, cfg.norm_eps)
            if mode == "prefill":
                y, xc = attn.gqa_forward(p["xattn"], x, cfg, cross_x=enc_out,
                                         return_cache=True)
                cache["x"] = xc
            else:
                y = attn.gqa_forward(p["xattn"], x, cfg, cross_x=enc_out)
            h = h + y
        x = apply_norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
        if kind.use_moe:
            y, aux = moe.moe_forward(p["moe"], x, cfg)
        else:
            y = apply_mlp(p["mlp"], x, cfg.act, self.dtype)
        return h + y, cache, aux

    # ------------------------------------------------------------------
    # block execution (decode: one token)
    # ------------------------------------------------------------------

    def _block_decode(self, p, h, kind: LayerKind, cache, pos, positions,
                      block_tables=None):
        cfg = self.cfg
        if kind.block == "mamba":
            x = apply_norm(p["ln1"], h, cfg.norm, cfg.norm_eps)
            y, mc = mamba2.mamba_decode(p["mamba"], x, cache["m"], cfg)
            return h + y, {"m": mc}
        new_cache = {}
        x = apply_norm(p["ln1"], h, cfg.norm, cfg.norm_eps)
        if "p" in cache:        # paged KV pool (layout owned by repro.dist)
            y, pc = attn.gqa_decode_paged(
                p["attn"], x, cache["p"], pos, block_tables, cfg,
                positions=positions if self.use_rope else None,
                use_rope=self.use_rope)
            new_cache["p"] = pc
            ac = None
        elif cfg.attention == "mla":
            y, ac = attn.mla_decode(p["attn"], x, cache["a"], pos, cfg,
                                    positions=positions)
        else:
            y, ac = attn.gqa_decode(
                p["attn"], x, cache["a"], pos, cfg, window=kind.window,
                positions=positions if self.use_rope else None,
                use_rope=self.use_rope)
        if ac is not None:
            new_cache["a"] = ac
        h = h + y
        if kind.cross and "x" in cache:
            x = apply_norm(p["lnx"], h, cfg.norm, cfg.norm_eps)
            y, _ = attn.gqa_decode(p["xattn"], x, cache["x"], pos, cfg,
                                   cross=True)
            new_cache["x"] = cache["x"]
            h = h + y
        x = apply_norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
        if kind.use_moe:
            y, _ = moe.moe_forward(p["moe"], x, cfg)
        else:
            y = apply_mlp(p["mlp"], x, cfg.act, self.dtype)
        return h + y, new_cache

    def _remat(self, fn):
        if self.cfg.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)
        return jax.checkpoint(fn)

    # ------------------------------------------------------------------
    # embedding / encoder front
    # ------------------------------------------------------------------

    def _embed(self, params, tokens, positions, vision_embeds,
               constrain: bool = False):
        cfg = self.cfg
        h = params["embed"]["table"].astype(self.dtype)[tokens]
        # the table is d-over-model sharded (§Perf iter 1); in the
        # inference paths, resolve the lookup result to batch-sharded ONCE
        # here, or every layer's f32 norm internals inherit a model-sharded
        # d and get re-gathered (2 GB f32 gathers per matmul on qwen2-vl
        # prefill — §Perf iter 6). Training is better WITHOUT it (the
        # constraint's transpose inflates the backward by ~50%).
        if constrain:
            h = logical_constraint(h, ("batch", None, None))
        if cfg.family == "vlm" and vision_embeds is not None:
            nv = vision_embeds.shape[1]
            h = jnp.concatenate(
                [vision_embeds.astype(self.dtype), h[:, nv:]], axis=1)
        if cfg.family == "audio":
            pos = jnp.arange(tokens.shape[1]) if positions is None else positions
            h = h + sinusoidal_embedding(pos, cfg.d_model).astype(self.dtype)
        return h

    def _default_positions(self, B, S, offset=0):
        pos = jnp.arange(offset, offset + S)[None, :]
        pos = jnp.broadcast_to(pos, (B, S))
        if self.cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[:, None, :], (B, 3, S))
        return pos

    def _encode(self, params, frames):
        """Whisper encoder on stub frame embeddings (B, enc_seq, d)."""
        cfg = self.cfg
        h = frames.astype(self.dtype)
        h = h + sinusoidal_embedding(
            jnp.arange(h.shape[1]), cfg.d_model).astype(self.dtype)
        kind = LayerKind("attn")

        def body(h, p):
            h, _, _ = self._block_full(p, h, kind, None, "encode")
            return h, None

        h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
        return apply_norm(params["encoder"]["norm"], h, cfg.norm, cfg.norm_eps)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def apply(self, params, tokens, *, positions=None, vision_embeds=None,
              frames=None):
        """Full-sequence forward. Returns (logits, aux_loss)."""
        cfg = self.cfg
        B, S = tokens.shape
        if positions is None:
            positions = self._default_positions(B, S)
        enc_out = self._encode(params, frames) if cfg.is_encoder_decoder else None
        h = self._embed(params, tokens, positions, vision_embeds)

        def unit_body(carry, unit_p):
            h, aux = carry
            if cfg.family == "hybrid":
                h, _, _ = self._block_full(params["shared"], h,
                                           LayerKind("attn"), positions,
                                           "train", enc_out)
            for i, kind in enumerate(self.unit_kinds):
                h, _, a = self._block_full(_tree_index(unit_p, i), h, kind,
                                           positions, "train", enc_out)
                aux = aux + a
            return (h, aux), None

        body = self._remat(unit_body) if cfg.remat else unit_body
        aux0 = jnp.zeros((), jnp.float32)
        if "blocks" in params:
            if cfg.scan_layers:
                (h, aux), _ = jax.lax.scan(body, (h, aux0), params["blocks"])
            else:
                carry = (h, aux0)
                for u in range(self.n_units):
                    carry, _ = body(carry, _tree_index(params["blocks"], u))
                h, aux = carry
        else:
            aux = aux0
        for i, kind in enumerate(self.tail_kinds):
            h, _, a = self._block_full(_tree_index(params["tail"], i), h,
                                       kind, positions, "train", enc_out)
            aux = aux + a
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = self._head(params, h)
        return logits, aux

    def _head(self, params, h):
        if self.cfg.tie_embeddings:
            return mdot(h, params["embed"]["table"].T, self.dtype)
        return mdot(h, params["head"]["w"], self.dtype)

    # -------------------------- prefill ------------------------------

    def prefill(self, params, tokens, *, cache_len: Optional[int] = None,
                positions=None, vision_embeds=None, frames=None,
                length=None):
        """Returns (last-token logits (B, vocab), cache).

        ``length``: optional scalar (may be traced) count of REAL tokens
        when ``tokens`` is right-padded to a fixed prefill bucket — the
        compiled serving engine pads prompts to a small set of lengths so
        warmup compiles a fixed program set. With ``length`` set, the
        returned logits are those of token ``length-1``, window caches
        arrange slots by real positions, and SSM states are exactly the
        state after ``length`` tokens (pad dt is zeroed). Full-length KV
        rows past ``length`` hold pad garbage, which decode never attends:
        each step writes position p before attending, and the attention
        mask admits only rows <= p."""
        cfg = self.cfg
        B, S = tokens.shape
        cache_len = cache_len or S
        if positions is None:
            positions = self._default_positions(B, S)
        enc_out = self._encode(params, frames) if cfg.is_encoder_decoder else None
        h = self._embed(params, tokens, positions, vision_embeds,
                        constrain=True)

        def pad_cache(c, kind: LayerKind):
            if kind.block == "mamba" or not c:
                return c
            out = dict(c)
            if "a" in c and "k" in c["a"]:
                L = c["a"]["k"].shape[1]
                tgt = min(cache_len, kind.window) if kind.window > 0 else cache_len
                if L < tgt:
                    out["a"] = {kk: jnp.pad(vv, ((0, 0), (0, tgt - L)) +
                                            ((0, 0),) * (vv.ndim - 2))
                                for kk, vv in c["a"].items()}
            elif "a" in c:  # mla latent cache
                L = c["a"]["c_kv"].shape[1]
                if L < cache_len:
                    out["a"] = {kk: jnp.pad(vv, ((0, 0), (0, cache_len - L), (0, 0)))
                                for kk, vv in c["a"].items()}
            return out

        def unit_body(h, unit_p):
            caches = {}
            if cfg.family == "hybrid":
                h, sc, _ = self._block_full(params["shared"], h,
                                            LayerKind("attn"), positions,
                                            "prefill", enc_out, length=length)
                caches["shared"] = pad_cache(sc, LayerKind("attn"))
            for i, kind in enumerate(self.unit_kinds):
                h, c, _ = self._block_full(_tree_index(unit_p, i), h, kind,
                                           positions, "prefill", enc_out,
                                           length=length)
                caches[str(i)] = pad_cache(c, kind)
            return h, caches

        cache: Dict[str, Any] = {}
        if "blocks" in params:
            if cfg.scan_layers:
                h, unit_caches = jax.lax.scan(unit_body, h, params["blocks"])
            else:
                per_unit = []
                for u in range(self.n_units):
                    h, c = unit_body(h, _tree_index(params["blocks"], u))
                    per_unit.append(c)
                unit_caches = _tree_stack(per_unit)
            cache["units"] = unit_caches
        for i, kind in enumerate(self.tail_kinds):
            h, c, _ = self._block_full(_tree_index(params["tail"], i), h,
                                       kind, positions, "prefill", enc_out,
                                       length=length)
            cache[f"t{i}"] = pad_cache(c, kind)
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        if length is None:
            h_last = h[:, -1:]
        else:
            h_last = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
        logits = self._head(params, h_last)[:, 0]
        return logits, cache

    # -------------------------- decode -------------------------------

    def decode(self, params, cache, token, pos, *, positions=None,
               block_tables=None):
        """One decode step. token: (B,1) int32; pos: scalar absolute
        position, or (B,) per-request positions (continuous batching).
        ``block_tables``: (B, M) int32 per-slot page tables, required when
        the cache holds paged (``p``-layout) KV pools.
        Returns (logits (B, vocab), new_cache)."""
        cfg = self.cfg
        B = token.shape[0]
        if positions is None:
            pa = jnp.asarray(pos)
            p1 = (pa[:, None] if pa.ndim == 1
                  else jnp.broadcast_to(pa[None, None], (B, 1)))
            positions = (jnp.broadcast_to(p1[:, None], (B, 3, 1))
                         if cfg.mrope_sections else p1)
        h = self._embed(params, token, positions, None, constrain=True)

        def unit_body(h, xs):
            unit_p, unit_c = xs
            new_c = {}
            if cfg.family == "hybrid":
                h, sc = self._block_decode(params["shared"], h,
                                           LayerKind("attn"),
                                           unit_c["shared"], pos, positions,
                                           block_tables)
                new_c["shared"] = sc
            for i, kind in enumerate(self.unit_kinds):
                h, c = self._block_decode(_tree_index(unit_p, i), h, kind,
                                          unit_c[str(i)], pos, positions,
                                          block_tables)
                new_c[str(i)] = c
            return h, new_c

        new_cache: Dict[str, Any] = {}
        if "blocks" in params:
            if cfg.scan_layers:
                h, nc = jax.lax.scan(unit_body, h, (params["blocks"],
                                                    cache["units"]))
            else:
                per_unit = []
                for u in range(self.n_units):
                    h, c = unit_body(h, (_tree_index(params["blocks"], u),
                                         _tree_index(cache["units"], u)))
                    per_unit.append(c)
                nc = _tree_stack(per_unit)
            new_cache["units"] = nc
        for i, kind in enumerate(self.tail_kinds):
            h, c = self._block_decode(_tree_index(params["tail"], i), h, kind,
                                      cache[f"t{i}"], pos, positions,
                                      block_tables)
            new_cache[f"t{i}"] = c
        h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        logits = self._head(params, h)[:, 0]
        return logits, new_cache

    # -------------------------- empty cache --------------------------

    def empty_cache(self, batch: int, cache_len: int, *, page_pool=None):
        """Zero-initialized cache (for dry-run decode lowering).

        ``page_pool``: optional ``(n_pages, page_size)`` — pageable layers
        (see ``pageable``) then hold a global ``p``-layout page pool
        instead of a per-slot ``a`` cache; non-pageable layers keep their
        dense layout, so one cache tree can mix both."""
        cfg = self.cfg
        dt = self.dtype

        def block_cache(kind: LayerKind):
            if kind.block == "mamba":
                return {"m": mamba2.mamba_empty_cache(cfg, batch, dt)}
            c = {}
            if page_pool is not None and self.pageable(kind):
                c["p"] = attn.gqa_empty_page_pool(cfg, *page_pool, dt)
            elif cfg.attention == "mla":
                c["a"] = attn.mla_empty_cache(cfg, batch, cache_len, dt)
            else:
                c["a"] = attn.gqa_empty_cache(cfg, batch, cache_len,
                                              kind.window, dt)
            if kind.cross and cfg.is_encoder_decoder:
                KVH, Dh = cfg.n_kv_heads, cfg.head_dim
                z = jnp.zeros((batch, cfg.encoder_seq, KVH, Dh), dt)
                c["x"] = {"k": z, "v": z}
            return c

        cache: Dict[str, Any] = {}
        if self.n_units:
            unit_c = {str(i): block_cache(k)
                      for i, k in enumerate(self.unit_kinds)}
            if cfg.family == "hybrid":
                unit_c["shared"] = block_cache(LayerKind("attn"))
            cache["units"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (self.n_units,) + a.shape),
                unit_c)
        for i, kind in enumerate(self.tail_kinds):
            cache[f"t{i}"] = block_cache(kind)
        return cache
