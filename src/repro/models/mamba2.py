"""Mamba-2 block (SSD): in_proj -> causal depthwise conv -> SSD scan ->
gated RMSNorm -> out_proj.  Train/prefill use the chunked SSD (Pallas on
TPU); decode keeps a (conv window, SSD state) cache — O(1) per token, which
is why the SSM archs run the `long_500k` shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd.ops import ssd_decode, ssd_scan
from repro.models.layers import dense_init, gated_rmsnorm, mdot


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    # dt bias init so softplus(dt_bias) spans [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(ks[3], (nh,))
    dt = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min))
                 + math.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))        # inverse softplus
    a0, a1 = s.a_init_range
    A = jax.random.uniform(ks[4], (nh,), minval=a0, maxval=a1)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * s.n_groups * s.d_state + nh)),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), fan_in=s.d_conv),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d), fan_in=d_in),
    }


def _split_proj(cfg: ModelConfig, proj):
    s = cfg.ssm
    d_in, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * gn]
    dt_raw = proj[..., d_in + d_in + 2 * gn:]
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b, dtype):
    """Depthwise causal conv via shifted adds (d_conv is tiny)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    S = xbc.shape[1]
    out = b.astype(dtype)
    acc = jnp.zeros_like(xbc)
    for i in range(K):
        acc = acc + w[i].astype(dtype) * pad[:, i:i + S]
    return jax.nn.silu(acc + out)


def mamba_forward(params, u, cfg: ModelConfig, *, return_cache: bool = False,
                  init_cache=None, length=None):
    """u: (B,S,d). Returns out or (out, cache{conv, state}).

    ``length``: optional scalar count of REAL tokens when u is right-padded
    to a prefill bucket. Padding is made inert by zeroing dt past ``length``
    (decay exp(0·A)=1, contribution dt·x·B=0, so the SSD final state is the
    state after exactly ``length`` tokens), and the conv cache gathers the
    last d_conv-1 REAL inputs instead of the padded tail."""
    s = cfg.ssm
    dtype = u.dtype
    B, S, d = u.shape
    d_in, nh, conv_dim = _dims(cfg)
    gn = s.n_groups * s.d_state

    proj = mdot(u, params["in_proj"], dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)

    if init_cache is not None:
        # prepend cached conv window (chunked prefill continuation)
        xbc_in = jnp.concatenate([init_cache["conv"].astype(dtype), xbc], axis=1)
        conv = _causal_conv(xbc_in, params["conv_w"], params["conv_b"], dtype)
        conv = conv[:, init_cache["conv"].shape[1]:]
    else:
        conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], dtype)

    x = conv[..., :d_in].reshape(B, S, nh, s.head_dim)
    Bm = conv[..., d_in:d_in + gn].reshape(B, S, s.n_groups, s.d_state)
    Cm = conv[..., d_in + gn:].reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    if length is not None:
        dt = jnp.where(jnp.arange(S)[None, :, None] < length, dt, 0.0)
    A = -jnp.exp(params["A_log"])

    y, final_state = ssd_scan(
        x, dt, A, Bm, Cm, params["D"],
        init_state=None if init_cache is None else init_cache["state"],
        chunk=s.chunk_size, impl=cfg.ssd_impl,
        design=cfg.ssd_design or None)
    y = y.astype(dtype).reshape(B, S, d_in)
    y = gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = mdot(y, params["out_proj"], dtype)
    if not return_cache:
        return out
    K1 = s.d_conv - 1
    if length is not None:
        idx = length - K1 + jnp.arange(K1)
        rows = jnp.take(xbc, jnp.clip(idx, 0, S - 1), axis=1)
        conv_cache = jnp.where((idx >= 0)[None, :, None], rows,
                               jnp.zeros_like(rows))
    else:
        conv_cache = xbc[:, -K1:] if S >= K1 else jnp.pad(
            xbc, ((0, 0), (K1 - S, 0), (0, 0)))
    return out, {"conv": conv_cache, "state": final_state}


def mamba_decode(params, u, cache, cfg: ModelConfig):
    """One-token decode. u: (B,1,d); cache{conv (B,K-1,conv_dim),
    state (B,nh,P,N)}. Returns (out, new_cache)."""
    s = cfg.ssm
    dtype = u.dtype
    B = u.shape[0]
    d_in, nh, conv_dim = _dims(cfg)
    gn = s.n_groups * s.d_state

    proj = mdot(u, params["in_proj"], dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = xbc[:, 0]                                       # (B, conv_dim)

    w = params["conv_w"].astype(dtype)                    # (K, conv_dim)
    hist = cache["conv"].astype(dtype)                    # (B, K-1, conv_dim)
    conv = jnp.sum(w[:-1][None] * hist, axis=1) + w[-1][None] * xbc
    conv = jax.nn.silu(conv + params["conv_b"].astype(dtype))
    new_conv = jnp.concatenate([hist[:, 1:], xbc[:, None]], axis=1)

    x = conv[..., :d_in].reshape(B, nh, s.head_dim)
    Bm = conv[..., d_in:d_in + gn].reshape(B, s.n_groups, s.d_state)
    Cm = conv[..., d_in + gn:].reshape(B, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, new_state = ssd_decode(x, dt, A, Bm, Cm, params["D"], cache["state"])
    y = y.astype(dtype).reshape(B, 1, d_in)
    y = gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = mdot(y, params["out_proj"], dtype)
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "state": new_state}


def mamba_empty_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
