"""Attention variants: GQA (+bias, sliding window, cross), MLA (latent KV).

Three execution modes per variant:
  * train  — full sequence, no cache returned
  * prefill — full sequence, returns the KV cache
  * decode — one token against a cache (full or circular sliding-window)

Caches are plain dicts of arrays so they stack cleanly across scanned layers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_attention
from repro.models.layers import apply_norm, apply_rope, dense_init, mdot, rope_cos_sin

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, cross: bool = False):
    d, H, KVH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh)),
        "wk": dense_init(ks[1], (d, KVH * Dh)),
        "wv": dense_init(ks[2], (d, KVH * Dh)),
        "wo": dense_init(ks[3], (H * Dh, d), fan_in=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bk"] = jnp.zeros((KVH * Dh,), jnp.float32)
        p["bv"] = jnp.zeros((KVH * Dh,), jnp.float32)
    return p


def _qkv(params, x, kv_x, cfg: ModelConfig, dtype):
    B, S, _ = x.shape
    Skv = kv_x.shape[1]
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = mdot(x, params["wq"], dtype)
    k = mdot(kv_x, params["wk"], dtype)
    v = mdot(kv_x, params["wv"], dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    return (q.reshape(B, S, H, Dh), k.reshape(B, Skv, KVH, Dh),
            v.reshape(B, Skv, KVH, Dh))


def _rope(cfg: ModelConfig, q, k, positions, kv_positions=None):
    if positions is None:
        return q, k
    cos_q, sin_q = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                                cfg.mrope_sections)
    q = apply_rope(q, cos_q, sin_q)
    if k is not None:
        kp = positions if kv_positions is None else kv_positions
        cos_k, sin_k = rope_cos_sin(kp, cfg.head_dim, cfg.rope_theta,
                                    cfg.mrope_sections)
        k = apply_rope(k, cos_k, sin_k)
    return q, k


def gqa_forward(params, x, cfg: ModelConfig, *, positions=None, window: int = 0,
                causal: bool = True, cross_x=None, return_cache: bool = False,
                length=None):
    """Train/prefill path. x: (B,S,d). cross_x: encoder output for cross-attn
    (no rope, no mask). Returns out or (out, cache). ``length``: optional
    scalar count of REAL tokens when x is right-padded to a prefill bucket —
    window caches then arrange slots by real positions (pad rows excluded)."""
    dtype = x.dtype
    kv_src = cross_x if cross_x is not None else x
    q, k, v = _qkv(params, x, kv_src, cfg, dtype)
    if cross_x is None:
        q, k = _rope(cfg, q, k, positions)
    out = flash_attention(
        q, k, v, causal=causal and cross_x is None, window=window,
        chunk=cfg.attention_chunk, impl=cfg.attention_impl,
        design=cfg.attention_design or None)
    B, S = x.shape[:2]
    out = mdot(out.reshape(B, S, -1), params["wo"], dtype)
    if not return_cache:
        return out
    if window > 0:
        k = _window_slots(k, window, length)
        v = _window_slots(v, window, length)
    return out, _maybe_quant_cache(cfg, k, v)


# ---------------------------------------------------------------------------
# int8 KV cache (symmetric per-(token, head) quantization)
# ---------------------------------------------------------------------------


def quantize_kv(x):
    """x: (..., Dh) -> (int8 values, f32 scale with trailing 1-dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _maybe_quant_cache(cfg: ModelConfig, k, v):
    if cfg.kv_cache_dtype != "int8":
        return {"k": k, "v": v}
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    return {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}


def _cache_kv(cfg: ModelConfig, cache, dtype):
    if "k_scale" in cache:
        return (dequantize_kv(cache["k"], cache["k_scale"], dtype),
                dequantize_kv(cache["v"], cache["v_scale"], dtype))
    return cache["k"], cache["v"]


def _window_slots(kv, window: int, length=None):
    """Arrange the last `window` entries into circular slot order.
    kv: (B,S,KVH,Dh) -> (B,window,KVH,Dh) where slot i holds the latest
    position p <= S-1 with p ≡ i (mod window), or zeros if none.
    ``length``: optional (traced) count of real tokens — rows past it are
    prefill-bucket padding and must not land in any slot."""
    B, S, KVH, Dh = kv.shape
    if length is not None:
        # dynamic form of the same rule, p = latest real pos ≡ i (mod W)
        i = jnp.arange(window)
        p = (length - 1) - jnp.mod(length - 1 - i, window)
        rows = jnp.take(kv, jnp.clip(p, 0, S - 1), axis=1)
        return jnp.where((p >= 0)[None, :, None, None], rows,
                         jnp.zeros_like(rows))
    if S <= window:
        return jnp.pad(kv, ((0, 0), (0, window - S), (0, 0), (0, 0)))
    last = kv[:, S - window:]                     # positions S-window .. S-1
    slots = (jnp.arange(S - window, S)) % window
    out = jnp.zeros((B, window, KVH, Dh), kv.dtype)
    return out.at[:, slots].set(last)


def _slot_positions(pos, cache_len: int, window: int):
    """Absolute position stored in each slot of a (possibly circular) cache
    after the token at `pos` has been written. -1 = empty.
    pos: scalar or (B,) — returns (L,) or (B, L) accordingly (per-request
    positions enable continuous batching)."""
    i = jnp.arange(cache_len)
    pos = jnp.asarray(pos)
    if pos.ndim == 1:
        i = i[None, :]
        pos = pos[:, None]
    if window > 0:
        p = pos - jnp.mod(pos - i, cache_len)
        return jnp.where(p >= 0, p, -1)
    return jnp.where(i <= pos, i, -1)


def gqa_decode(params, x, cache, pos, cfg: ModelConfig, *, window: int = 0,
               positions=None, cross: bool = False, use_rope: bool = True):
    """One-token decode. x: (B,1,d); cache{k,v}: (B,L,KVH,Dh); pos: scalar
    absolute position. positions: optional (B,1) or (B,3,1) for M-RoPE.
    Returns (out, new_cache)."""
    dtype = x.dtype
    B = x.shape[0]
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cross:
        # cross-attention cache is static (encoder kv); only compute q
        q = mdot(x, params["wq"], dtype)
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dtype)
        q = q.reshape(B, 1, H, Dh)
        k, v = _cache_kv(cfg, cache, dtype)
        out = _cache_attend(q, k, v, kpos=None)
        return mdot(out.reshape(B, 1, -1), params["wo"], dtype), cache

    q, k_new, v_new = _qkv(params, x, x, cfg, dtype)
    pos = jnp.asarray(pos)
    vec = pos.ndim == 1                     # per-request positions
    if use_rope:
        if positions is None:
            positions = (pos[:, None] if vec
                         else jnp.full((B, 1), pos)).astype(jnp.int32)
        q, k_new = _rope(cfg, q, k_new, positions)

    L = cache["k"].shape[1]
    slot = jnp.mod(pos, L) if window > 0 else pos

    def upd(buf, new):
        if vec:
            return buf.at[jnp.arange(B), slot].set(
                new[:, 0].astype(buf.dtype))
        if window == 0:
            return jax.lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), slot, axis=1)
        return buf.at[:, slot].set(new[:, 0].astype(buf.dtype))

    if "k_scale" in cache:      # int8 cache: quantize the new token
        knq, kns = quantize_kv(k_new)
        vnq, vns = quantize_kv(v_new)
        new_cache = {"k": upd(cache["k"], knq),
                     "k_scale": upd(cache["k_scale"], kns),
                     "v": upd(cache["v"], vnq),
                     "v_scale": upd(cache["v_scale"], vns)}
    else:
        new_cache = {"k": upd(cache["k"], k_new), "v": upd(cache["v"], v_new)}
    k, v = _cache_kv(cfg, new_cache, dtype)

    kpos = _slot_positions(pos, L, window)
    out = _cache_attend(q, k, v, kpos=kpos)
    out = mdot(out.reshape(B, 1, -1), params["wo"], dtype)
    return out, new_cache


def _cache_attend(q, k, v, kpos):
    """Single-query attention over a cache. q: (B,1,H,Dh); k/v: (B,L,KVH,Dh);
    kpos: (L,) or per-request (B,L) absolute positions, or None (cross)."""
    B, _, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = Dh ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, KVH, G, Dh)
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k.astype(jnp.float32))
    if kpos is not None:
        kp = kpos if kpos.ndim == 2 else kpos[None, :]
        s = jnp.where(kp[:, None, None, :] >= 0, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgl,blhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H * Dh).astype(q.dtype)


def gqa_empty_cache(cfg: ModelConfig, batch: int, cache_len: int, window: int,
                    dtype):
    L = min(cache_len, window) if window > 0 else cache_len
    KVH, Dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        zq = jnp.zeros((batch, L, KVH, Dh), jnp.int8)
        zs = jnp.full((batch, L, KVH, 1), 1e-8 / 127.0, jnp.float32)
        return {"k": zq, "k_scale": zs, "v": zq, "v_scale": zs}
    z = jnp.zeros((batch, L, KVH, Dh), dtype)
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# paged KV pool (vLLM-style block tables, JAX static shapes)
# ---------------------------------------------------------------------------


def gqa_empty_page_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                        dtype):
    """Global device-resident KV page pool shared by every slot:
    ``(n_pages, page_size, KVH, Dh)`` per leaf. Page 0 is RESERVED as the
    null page — block-table entries of unallocated regions (and of freed
    slots) point at it, so out-of-extent cache writes land in garbage that
    the position mask never admits."""
    KVH, Dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        zq = jnp.zeros((n_pages, page_size, KVH, Dh), jnp.int8)
        zs = jnp.full((n_pages, page_size, KVH, 1), 1e-8 / 127.0,
                      jnp.float32)
        return {"k": zq, "k_scale": zs, "v": zq, "v_scale": zs}
    z = jnp.zeros((n_pages, page_size, KVH, Dh), dtype)
    return {"k": z, "v": z}


def gqa_decode_paged(params, x, cache, pos, block_tables, cfg: ModelConfig,
                     *, positions=None, use_rope: bool = True):
    """One-token decode against a paged KV pool.

    cache leaves: ``(n_pages, page_size, KVH, Dh)`` global pool;
    ``block_tables``: (B, M) int32 page ids per slot (entry 0 = the
    reserved null page); pos: (B,) per-request absolute positions.

    The new token writes to ``pool[bt[b, pos//P], pos % P]`` and attention
    gathers each slot's pages back into a contiguous (B, M*P) view. Rows
    <= pos of that view hold exactly the values a dense per-slot cache
    would (the engine scatters prefill rows page-aligned), and rows > pos
    are masked to an exact-zero softmax contribution — so greedy tokens
    match the dense layout bitwise. Returns (out, new_cache)."""
    dtype = x.dtype
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, x, x, cfg, dtype)
    pos = jnp.asarray(pos)
    if use_rope:
        if positions is None:
            positions = pos[:, None].astype(jnp.int32)
        q, k_new = _rope(cfg, q, k_new, positions)

    P = cache["k"].shape[1]                       # page_size
    M = block_tables.shape[1]
    page = block_tables[jnp.arange(B), pos // P]  # (B,) write page per slot
    off = jnp.mod(pos, P)

    def upd(buf, new):
        # each active slot owns its write page exclusively; frozen slots
        # point at their own pages or the null page — never another slot's
        return buf.at[page, off].set(new[:, 0].astype(buf.dtype))

    if "k_scale" in cache:      # int8 pool: quantize the new token
        knq, kns = quantize_kv(k_new)
        vnq, vns = quantize_kv(v_new)
        new_cache = {"k": upd(cache["k"], knq),
                     "k_scale": upd(cache["k_scale"], kns),
                     "v": upd(cache["v"], vnq),
                     "v_scale": upd(cache["v_scale"], vns)}
    else:
        new_cache = {"k": upd(cache["k"], k_new),
                     "v": upd(cache["v"], v_new)}

    def gather(buf):
        g = jnp.take(buf, block_tables, axis=0)   # (B, M, P, ...)
        return g.reshape((B, M * P) + buf.shape[2:])

    k, v = _cache_kv(cfg, {kk: gather(vv) for kk, vv in new_cache.items()},
                     dtype)
    kpos = _slot_positions(pos, M * P, 0)
    out = _cache_attend(q, k, v, kpos=kpos)
    out = mdot(out.reshape(B, 1, -1), params["wo"], dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), jnp.float32)},
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qh), fan_in=m.q_lora_rank),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)},
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim),
                           fan_in=m.kv_lora_rank),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim),
                           fan_in=m.kv_lora_rank),
        "wo": dense_init(ks[5], (H * m.v_head_dim, d), fan_in=H * m.v_head_dim),
    }


def _mla_q(params, x, cfg, positions, dtype):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm(params["q_norm"], mdot(x, params["wq_a"], dtype),
                       "rmsnorm", cfg.norm_eps)
    q = mdot(q_lat, params["wq_b"], dtype).reshape(B, S, H, qh)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if positions is not None:
        cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(params, x, cfg, positions, dtype):
    m = cfg.mla
    kv = mdot(x, params["wkv_a"], dtype)
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = apply_norm(params["kv_norm"], c_kv, "rmsnorm", cfg.norm_eps)
    k_rope = k_rope[:, :, None, :]                       # (B,S,1,rope)
    if positions is not None:
        cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
        k_rope = apply_rope(k_rope, cos, sin)
    return c_kv, k_rope[:, :, 0, :]


def mla_forward(params, x, cfg: ModelConfig, *, positions=None,
                return_cache: bool = False):
    """Expanded (train/prefill) MLA: materialize per-head K/V, flash attn."""
    m = cfg.mla
    dtype = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, cfg, positions, dtype)
    c_kv, k_rope = _mla_latent(params, x, cfg, positions, dtype)

    k_nope = mdot(c_kv, params["wk_b"], dtype).reshape(B, S, H, m.qk_nope_head_dim)
    v = mdot(c_kv, params["wv_b"], dtype).reshape(B, S, H, m.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    # pad v head dim up to qk head dim for the shared kernel, slice after
    qh = q.shape[-1]
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qh - m.v_head_dim)))
    out = flash_attention(q, k, vpad, causal=True, chunk=cfg.attention_chunk,
                          impl=cfg.attention_impl, scale=qh ** -0.5,
                          design=cfg.attention_design or None)
    out = out[..., :m.v_head_dim].reshape(B, S, -1)
    out = mdot(out, params["wo"], dtype)
    if not return_cache:
        return out
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(params, x, cache, pos, cfg: ModelConfig, positions=None):
    """Absorbed-latent decode: attention runs in the kv_lora_rank space —
    the cache is (B, L, r + rope) instead of per-head K/V (MLA's serving win).
    """
    m = cfg.mla
    dtype = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    pos = jnp.asarray(pos)
    vec = pos.ndim == 1
    if positions is None:
        positions = (pos[:, None] if vec
                     else jnp.full((B, 1), pos)).astype(jnp.int32)
    q_nope, q_rope = _mla_q(params, x, cfg, positions, dtype)   # (B,1,H,·)
    c_new, kr_new = _mla_latent(params, x, cfg, positions, dtype)

    if vec:
        rows = jnp.arange(B)
        c_kv = cache["c_kv"].at[rows, pos].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, pos].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos,
            axis=1)

    L = c_kv.shape[1]
    wk_b = params["wk_b"].astype(dtype).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    # absorb: q' = q_nope @ W_k^T per head -> latent space
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b)       # (B,H,r)
    s = jnp.einsum("bhr,blr->bhl", q_lat, c_kv.astype(dtype))
    s = s + jnp.einsum("bhd,bld->bhl", q_rope[:, 0], k_rope.astype(dtype))
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = s.astype(jnp.float32) * (qh ** -0.5)
    limit = pos[:, None, None] if vec else pos
    s = jnp.where(jnp.arange(L)[None, None, :] <= limit, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhl,blr->bhr", p.astype(dtype), c_kv.astype(dtype))
    wv_b = params["wv_b"].astype(dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wv_b).reshape(B, 1, -1)
    out = mdot(o, params["wo"], dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_empty_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
    }
