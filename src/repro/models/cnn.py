"""Paper-faithful CIFAR-analog CNN with BatchNorm (davidcpage/cifar10-fast
ResNet9 style, the model the SWAP paper trains).

Functional BN: ``apply`` returns the per-batch statistics so phase 3 of SWAP
can recompute running statistics for the *averaged* weights — Algorithm 1
line 28 of the paper. ``state`` holds the running (mean, var) used at eval.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _conv_init(key, shape):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape) * (2.0 / fan_in) ** 0.5


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def init_cnn(key, cfg: ModelConfig):
    chans = cfg.cnn_channels
    params, state = {}, {}
    prev = 3
    keys = jax.random.split(key, 2 * len(chans) + 4 + 1)
    ki = 0

    def add_conv_bn(name, cin, cout):
        nonlocal ki
        params[name] = {
            "w": _conv_init(keys[ki], (3, 3, cin, cout)),
            "scale": jnp.ones((cout,)), "bias": jnp.zeros((cout,)),
        }
        state[name] = {"mean": jnp.zeros((cout,)), "var": jnp.ones((cout,))}
        ki += 1

    for i, c in enumerate(chans):
        add_conv_bn(f"conv{i}", prev, c)
        # residual pair on the 2nd and last stages (resnet9 pattern)
        if i in (1, len(chans) - 1):
            add_conv_bn(f"res{i}a", c, c)
            add_conv_bn(f"res{i}b", c, c)
        prev = c
    params["fc"] = {"w": jax.random.normal(keys[ki], (prev, cfg.n_classes)) * 0.01}
    return params, state


def _bn(p, s, x, train: bool, momentum: float = 0.9):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
    else:
        mean, var = s["mean"], s["var"]
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    if train:
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        new_s = s
    return y, new_s


def apply_cnn(params, state, x, cfg: ModelConfig, train: bool):
    """x: (B, H, W, 3). Returns (logits (B, n_classes), new_state)."""
    chans = cfg.cnn_channels
    new_state = {}

    def conv_bn(name, h):
        y = _conv(h, params[name]["w"])
        y, new_state[name] = _bn(params[name], state[name], y, train)
        return jax.nn.relu(y)

    h = x
    for i, c in enumerate(chans):
        h = conv_bn(f"conv{i}", h)
        if i > 0:
            h = _maxpool(h)
        if i in (1, len(chans) - 1):
            r = conv_bn(f"res{i}a", h)
            r = conv_bn(f"res{i}b", r)
            h = h + r
    h = jnp.max(h, axis=(1, 2))                       # global max pool
    logits = h @ params["fc"]["w"] * 0.125            # cifar10-fast scale
    return logits, new_state


def cnn_batch_stats(params, x, cfg: ModelConfig):
    """One forward pass collecting raw batch statistics per BN layer —
    used by SWAP phase 3 to rebuild running stats for averaged weights."""
    stats = {}

    def conv_bn(name, h):
        y = _conv(h, params[name]["w"])
        mean = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
        stats[name] = {"mean": mean, "var": var}
        y = (y - mean) * jax.lax.rsqrt(var + 1e-5) * params[name]["scale"] \
            + params[name]["bias"]
        return jax.nn.relu(y)

    chans = cfg.cnn_channels
    h = x
    for i, c in enumerate(chans):
        h = conv_bn(f"conv{i}", h)
        if i > 0:
            h = _maxpool(h)
        if i in (1, len(chans) - 1):
            r = conv_bn(f"res{i}a", h)
            r = conv_bn(f"res{i}b", r)
            h = h + r
    return stats
