"""Mixture-of-experts FFN with top-k routing and capacity-gather dispatch.

TPU/GSPMD adaptation: instead of the (tokens, experts, capacity) one-hot
dispatch einsum of GShard (whose dispatch tensor alone would be GBs at our
shapes) we use a *gather-based* dispatch:

  1. top-k routing per token, position-in-expert via a cumulative count;
  2. gather tokens into a dense (batch, experts, capacity, d) block —
     this is the all-to-all boundary when experts are sharded on `model`;
  3. one batched einsum per expert weight (MXU-dense, no ragged shapes);
  4. gather-back + weighted combine.

Everything is shape-static, so it lowers under pjit for any mesh; dropped
tokens (capacity overflow) lose their expert contribution, standard for
capacity-factor MoE. Router aux load-balance loss follows Switch/GShard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import logical_constraint
from repro.models.layers import dense_init, mdot


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, m.n_experts)),
        "wi": dense_init(ks[1], (m.n_experts, d, m.d_ff)),
        "wg": dense_init(ks[2], (m.n_experts, d, m.d_ff)),
        "wo": dense_init(ks[3], (m.n_experts, m.d_ff, d), fan_in=m.d_ff),
    }


def capacity(cfg: ModelConfig, seq: int) -> int:
    m = cfg.moe
    c = int(seq * m.top_k / m.n_experts * m.capacity_factor)
    return max(4, min(seq, (c + 3) // 4 * 4))


def moe_forward(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar f32)."""
    m = cfg.moe
    B, S, d = x.shape
    dtype = x.dtype
    K, E = m.top_k, m.n_experts
    C = capacity(cfg, S)

    logits = mdot(x, params["router"], jnp.float32)        # router in f32
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (B,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                                  # (E,)
    assign1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight

    # position of each (token, k) within its expert, in (s, k) scan order
    flat_e = expert_idx.reshape(B, S * K)                              # (B,SK)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)                # (B,SK,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                          # (B,SK,E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C                                                     # (B,SK)

    # scatter token indices into (B, E, C) dispatch slots
    tok_idx = jnp.tile(jnp.arange(S * K) // K, (B, 1))                 # (B,SK)
    safe_pos = jnp.where(keep, pos, C)                                 # drop -> C
    dispatch = jnp.zeros((B, E, C + 1), jnp.int32)
    filled = jnp.zeros((B, E, C + 1), bool)
    bidx = jnp.arange(B)[:, None]
    dispatch = dispatch.at[bidx, flat_e, safe_pos].set(tok_idx)
    filled = filled.at[bidx, flat_e, safe_pos].set(True)
    dispatch, filled = dispatch[..., :C], filled[..., :C]              # (B,E,C)

    # gather tokens -> dense expert blocks
    xg = jnp.take_along_axis(
        x, dispatch.reshape(B, E * C)[:, :, None], axis=1)
    xg = xg.reshape(B, E, C, d) * filled[..., None].astype(dtype)
    # dispatched blocks: batch over data, experts over model (the
    # all-to-all boundary when expert-parallel); keeps the expert matmuls
    # free of data-axis partial sums (§Perf iter 2)
    xg = logical_constraint(xg, ("batch", "experts", None, None))

    h = jnp.einsum("becd,edf->becf", xg, params["wi"].astype(dtype))
    g = jnp.einsum("becd,edf->becf", xg, params["wg"].astype(dtype))
    y = jnp.einsum("becf,efd->becd", h * jax.nn.silu(g),
                   params["wo"].astype(dtype))              # (B,E,C,d)
    y = logical_constraint(y, ("batch", "experts", None, None))

    # gather back per (token, k): flat slot index e*C + pos
    slot = flat_e * C + jnp.minimum(safe_pos, C - 1)                   # (B,SK)
    yk = jnp.take_along_axis(
        y.reshape(B, E * C, d), slot[:, :, None], axis=1)              # (B,SK,d)
    w = (gate_vals.reshape(B, S * K) * keep.astype(jnp.float32)).astype(dtype)
    out = jnp.sum((yk * w[..., None]).reshape(B, S, K, d), axis=2)
    return out, aux


def moe_forward_dense(params, x, cfg: ModelConfig):
    """Dense fallback: every expert on every token (oracle for tests)."""
    m = cfg.moe
    dtype = x.dtype
    logits = mdot(x, params["router"], jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = jax.vmap(lambda i, v: jnp.zeros((m.n_experts,), jnp.float32).at[i].set(v))(
        expert_idx.reshape(-1, m.top_k),
        gate_vals.reshape(-1, m.top_k)).reshape(probs.shape)

    h = jnp.einsum("bsd,edf->bsef", x, params["wi"].astype(dtype))
    g = jnp.einsum("bsd,edf->bsef", x, params["wg"].astype(dtype))
    y = jnp.einsum("bsef,efd->bsed", h * jax.nn.silu(g),
                   params["wo"].astype(dtype))
    out = jnp.einsum("bsed,bse->bsd", y, gates.astype(dtype))

    me = jnp.mean(probs, axis=(0, 1))
    assign1 = jax.nn.one_hot(expert_idx[..., 0], m.n_experts, dtype=jnp.float32)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss_weight
    return out, aux
