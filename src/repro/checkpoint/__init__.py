from repro.checkpoint.io import load_pytree, save_pytree
from repro.checkpoint.state import (
    Checkpointer, find_resume_point, list_checkpoints, load_train_state,
    save_train_state, state_step,
)
