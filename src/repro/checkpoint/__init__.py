from repro.checkpoint.io import load_pytree, save_pytree
from repro.checkpoint.state import (
    Checkpointer, find_latest_publish, find_resume_point, list_checkpoints,
    list_publishes, load_publish, load_train_state, save_publish,
    save_train_state, state_step,
)
