from repro.checkpoint.io import load_pytree, save_pytree
