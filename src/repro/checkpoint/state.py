"""Train-state checkpointing: periodic snapshots + exact mid-phase resume.

A layer over ``save_pytree``/``load_pytree`` that understands the phase
engine's ``TrainState`` (``repro.train.loop``):

  * ``save_train_state`` / ``load_train_state`` — byte-exact round trip of a
    whole TrainState (bundle, optimizer state, step, EMA, phase tag, rng),
    including the phase-2 stacked form with a leading W worker axis. A JSON
    sidecar (``<file>.json``) carries the metadata needed to pick a resume
    point without deserializing arrays.
  * ``Checkpointer`` — periodic snapshots at epoch-aligned steps
    (``maybe_save`` fires when ``step % every == 0``), with pruning of old
    snapshots per tag. Tags: ``phase1`` (mid-phase-1), ``phase1_final``
    (phase-1 result + its summary metrics, the anchor for phase-2 resume),
    ``phase2`` (mid-phase-2 stacked state).
  * ``find_resume_point`` — newest usable snapshot in a directory, in
    resume-priority order phase2 > phase1_final > phase1.
  * publish snapshots — ``save_publish`` / ``list_publishes`` /
    ``find_latest_publish`` / ``load_publish``: the *publishable* averaged
    parameter tree the live-serving path consumes
    (``repro.serve.publish``). Publish files are plain param pytrees, NOT
    TrainStates, and are deliberately invisible to ``list_checkpoints`` /
    ``find_resume_point`` — a training resume must never restart from an
    averaged model. They carry the same atomic-write guarantee
    (sidecar-before-snapshot, write-then-rename), so a follower polling
    the directory can never observe a torn generation.

Restores are exact: the resumed run executes the same compiled epoch chunks
on bit-identical state, so its parameters and metric logs match an
uninterrupted run bitwise (asserted by ``tests/test_resume.py``). On a
worker mesh, the caller re-places the loaded stacked state with
``dist.sharding.ensemble_shardings`` (see ``SWAP._place_ensemble``).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.io import atomic_write, load_pytree, save_pytree
from repro.train.loop import TrainState

_FILE_RE = re.compile(r"^(phase1_final|phase1|phase2)-step(\d+)\.msgpack$")
# resume priority: a phase2 snapshot supersedes phase1_final supersedes phase1
_TAG_ORDER = {"phase1": 0, "phase1_final": 1, "phase2": 2}
# publishable averaged-params snapshots (NOT resume points — excluded from
# _FILE_RE above so list_checkpoints/find_resume_point never see them)
_PUBLISH_RE = re.compile(r"^publish-gen(\d+)-step(\d+)\.msgpack$")


def _state_tree(state: TrainState) -> Dict[str, Any]:
    return dict(state._asdict())


def state_step(state: TrainState) -> int:
    """Global step of a state; phase-2 stacked states store one step per
    worker (always equal — workers advance in lockstep epochs)."""
    return int(np.asarray(state.step).reshape(-1)[0])


def save_train_state(path: str, state: TrainState,
                     meta: Optional[Dict[str, Any]] = None) -> None:
    # sidecar BEFORE the snapshot, both via atomic write-then-rename: the
    # .msgpack is what directory scans key off, so a kill anywhere in here
    # leaves either a complete (snapshot, meta) pair or nothing visible
    atomic_write(path + ".json",
                 json.dumps(meta or {}, indent=1).encode())
    save_pytree(path, _state_tree(state))


def load_train_state(path: str, template: TrainState) -> TrainState:
    """Restore a TrainState into the structure/shapes of ``template`` (built
    by the resuming process from the same config — e.g. the freshly stacked
    phase-2 state for a mid-phase-2 restore).

    Snapshots written before the precision subsystem carry no ``scale``
    leaves; those backfill from the template (the policy's initial
    loss-scale state), so old checkpoints stay resumable — bit-exact for
    f32 runs, where the scale state is a constant."""
    tree = load_pytree(path, _state_tree(template),
                       optional_prefixes=("scale/",))
    return TrainState(**tree)


def checkpoint_workers(meta: Dict[str, Any]) -> Optional[int]:
    """Worker count recorded in a phase-2 snapshot's sidecar meta, or None
    for pre-elastic snapshots (which implicitly match the resuming config)."""
    n = meta.get("n_workers")
    return int(n) if n is not None else None


def shrink_worker_axis(state: TrainState, n_workers: int) -> TrainState:
    """Keep the first ``n_workers`` workers of a phase-2 stacked state.

    Worker-count-aware resume: a checkpoint written by a W-worker run may
    be resumed by a run configured for W' < W workers (an elastic
    deployment that lost hosts) — the surviving workers keep their exact
    trajectories; the dropped tail is discarded. Growing the ensemble
    (W' > W) is refused: freshly cloned workers would share a trajectory
    with an existing one, which breaks the independence the phase-2
    average relies on — restart phase 2 from ``phase1_final`` instead."""
    ckpt_w = int(np.asarray(state.step).reshape(-1).shape[0])
    if n_workers == ckpt_w:
        return state
    if n_workers > ckpt_w:
        raise ValueError(
            f"cannot resume a {ckpt_w}-worker phase-2 checkpoint with "
            f"n_workers={n_workers}: cloned workers would not be "
            f"independent. Shrinking (n_workers <= {ckpt_w}) is supported; "
            f"to grow the ensemble, restart phase 2 from phase1_final.")
    import jax
    return jax.tree_util.tree_map(lambda a: a[:n_workers], state)


def read_meta(path: str) -> Dict[str, Any]:
    try:
        with open(path + ".json") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def list_checkpoints(directory: str) -> List[Dict[str, Any]]:
    """All snapshots in ``directory`` as dicts {path, tag, step, meta}."""
    if not directory or not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        m = _FILE_RE.match(name)
        if not m:
            continue
        path = os.path.join(directory, name)
        out.append({"path": path, "tag": m.group(1),
                    "step": int(m.group(2)), "meta": read_meta(path)})
    return out


def find_resume_point(directory: str) -> Optional[Dict[str, Any]]:
    """The snapshot a resumed run should restart from, or None.

    Highest (tag priority, step): the newest phase2 snapshot if any, else
    phase1_final, else the newest mid-phase-1 snapshot.
    """
    ckpts = list_checkpoints(directory)
    if not ckpts:
        return None
    return max(ckpts, key=lambda c: (_TAG_ORDER[c["tag"]], c["step"]))


def publish_path(directory: str, generation: int, step: int) -> str:
    return os.path.join(
        directory, f"publish-gen{generation:08d}-step{step:08d}.msgpack")


def save_publish(directory: str, generation: int, step: int, params,
                 meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write a publishable averaged-params snapshot.

    Same kill-anywhere contract as ``save_train_state``: the sidecar goes
    first, then the snapshot, each via write-then-rename — the ``.msgpack``
    is what ``find_latest_publish`` keys off, so a crash between the two
    writes leaves at worst a stray sidecar, never a loadable torn
    generation."""
    os.makedirs(directory, exist_ok=True)
    path = publish_path(directory, generation, step)
    atomic_write(path + ".json",
                 json.dumps(dict(meta or {}, generation=generation,
                                 step=step), indent=1).encode())
    save_pytree(path, params)
    return path


def list_publishes(directory: str) -> List[Dict[str, Any]]:
    """Complete publish snapshots in ``directory`` as
    {path, generation, step, meta}, ordered by generation."""
    if not directory or not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        m = _PUBLISH_RE.match(name)
        if not m:
            continue
        path = os.path.join(directory, name)
        out.append({"path": path, "generation": int(m.group(1)),
                    "step": int(m.group(2)), "meta": read_meta(path)})
    return sorted(out, key=lambda p: p["generation"])


def find_latest_publish(directory: str) -> Optional[Dict[str, Any]]:
    """Newest complete publish snapshot, or None. Atomic renames guarantee
    any listed ``.msgpack`` is complete, so the newest is always safe to
    load — a publisher killed mid-write is simply not visible yet."""
    pubs = list_publishes(directory)
    return pubs[-1] if pubs else None


def load_publish(path: str, template) -> Any:
    """Restore a published parameter tree into ``template``'s structure."""
    return load_pytree(path, template)


class Checkpointer:
    """Periodic epoch-aligned snapshots of a TrainState.

    ``every`` is a step count; because the phase engine only surfaces state
    at epoch-chunk boundaries, a snapshot is written at the first boundary
    that is >= ``every`` steps past the previous snapshot (so any
    ``every`` produces a usable cadence; a multiple of steps_per_epoch
    makes it exact). ``keep`` bounds snapshots retained per rolling tag;
    ``phase1_final`` is never pruned (phase-2 resume needs it).
    """

    def __init__(self, directory: str, every: int = 0, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        # seed the cadence from snapshots already on disk: a RESUMED run
        # must not re-snapshot at its first boundary regardless of how far
        # it is from the last durable step
        self._last_saved: Dict[str, int] = {}
        if directory:
            os.makedirs(directory, exist_ok=True)
            for c in list_checkpoints(directory):
                self._last_saved[c["tag"]] = max(
                    self._last_saved.get(c["tag"], 0), c["step"])

    def _path(self, tag: str, step: int) -> str:
        return os.path.join(self.directory, f"{tag}-step{step:08d}.msgpack")

    def save(self, tag: str, state: TrainState,
             meta: Optional[Dict[str, Any]] = None) -> str:
        step = state_step(state)
        path = self._path(tag, step)
        save_train_state(path, state, dict(meta or {}, tag=tag, step=step))
        self._last_saved[tag] = step
        self._prune(tag)
        return path

    def maybe_save(self, tag: str, state: TrainState,
                   meta: Optional[Dict[str, Any]] = None) -> Optional[str]:
        if self.every <= 0:
            return None
        step = state_step(state)
        if step <= 0 or step - self._last_saved.get(tag, 0) < self.every:
            return None
        return self.save(tag, state, meta)

    def _prune(self, tag: str) -> None:
        if tag == "phase1_final" or self.keep <= 0:
            return
        mine = [c for c in list_checkpoints(self.directory)
                if c["tag"] == tag]
        for stale in mine[:-self.keep]:
            for p in (stale["path"], stale["path"] + ".json"):
                try:
                    os.remove(p)
                except OSError:
                    pass
