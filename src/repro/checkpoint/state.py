"""Train-state checkpointing: periodic snapshots + exact mid-phase resume.

A layer over ``save_pytree``/``load_pytree`` that understands the phase
engine's ``TrainState`` (``repro.train.loop``):

  * ``save_train_state`` / ``load_train_state`` — byte-exact round trip of a
    whole TrainState (bundle, optimizer state, step, EMA, phase tag, rng),
    including the phase-2 stacked form with a leading W worker axis. A JSON
    sidecar (``<file>.json``) carries the metadata needed to pick a resume
    point without deserializing arrays.
  * ``Checkpointer`` — periodic snapshots at epoch-aligned steps
    (``maybe_save`` fires when ``step % every == 0``), with pruning of old
    snapshots per tag. Tags: ``phase1`` (mid-phase-1), ``phase1_final``
    (phase-1 result + its summary metrics, the anchor for phase-2 resume),
    ``phase2`` (mid-phase-2 stacked state).
  * ``find_resume_point`` — newest usable snapshot in a directory, in
    resume-priority order phase2 > phase1_final > phase1.
  * publish snapshots — ``save_publish`` / ``list_publishes`` /
    ``find_latest_publish`` / ``load_publish``: the *publishable* averaged
    parameter tree the live-serving path consumes
    (``repro.serve.publish``). Publish files are plain param pytrees, NOT
    TrainStates, and are deliberately invisible to ``list_checkpoints`` /
    ``find_resume_point`` — a training resume must never restart from an
    averaged model. They carry the same atomic-write guarantee
    (sidecar-before-snapshot, write-then-rename), so a follower polling
    the directory can never observe a torn generation.

Restores are exact: the resumed run executes the same compiled epoch chunks
on bit-identical state, so its parameters and metric logs match an
uninterrupted run bitwise (asserted by ``tests/test_resume.py``). On a
worker mesh, the caller re-places the loaded stacked state with
``dist.sharding.ensemble_shardings`` (see ``SWAP._place_ensemble``).
"""
from __future__ import annotations

import json
import os
import re
import warnings
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.io import (ChecksumError, atomic_write, checksum_bytes,
                                 load_pytree, pack_pytree, payload_intact,
                                 save_pytree)
from repro.train.loop import TrainState

_FILE_RE = re.compile(r"^(phase1_final|phase1|phase2)-step(\d+)\.msgpack$")
# resume priority: a phase2 snapshot supersedes phase1_final supersedes phase1
_TAG_ORDER = {"phase1": 0, "phase1_final": 1, "phase2": 2}
# publishable averaged-params snapshots (NOT resume points — excluded from
# _FILE_RE above so list_checkpoints/find_resume_point never see them)
_PUBLISH_RE = re.compile(r"^publish-gen(\d+)-step(\d+)\.msgpack$")


def _state_tree(state: TrainState) -> Dict[str, Any]:
    return dict(state._asdict())


def state_step(state: TrainState) -> int:
    """Global step of a state; phase-2 stacked states store one step per
    worker (always equal — workers advance in lockstep epochs)."""
    return int(np.asarray(state.step).reshape(-1)[0])


def save_train_state(path: str, state: TrainState,
                     meta: Optional[Dict[str, Any]] = None) -> None:
    # sidecar BEFORE the snapshot, both via atomic write-then-rename: the
    # .msgpack is what directory scans key off, so a kill anywhere in here
    # leaves either a complete (snapshot, meta) pair or nothing visible.
    # The sidecar records the content checksum of the bytes about to land,
    # so loads (and find_resume_point) can detect out-of-band corruption.
    tree = _state_tree(state)
    meta = dict(meta or {}, checksum=checksum_bytes(pack_pytree(tree)))
    atomic_write(path + ".json", json.dumps(meta, indent=1).encode())
    save_pytree(path, tree)


def load_train_state(path: str, template: TrainState,
                     verify: bool = True) -> TrainState:
    """Restore a TrainState into the structure/shapes of ``template`` (built
    by the resuming process from the same config — e.g. the freshly stacked
    phase-2 state for a mid-phase-2 restore).

    Snapshots written before the precision subsystem carry no ``scale``
    leaves; those backfill from the template (the policy's initial
    loss-scale state), so old checkpoints stay resumable — bit-exact for
    f32 runs, where the scale state is a constant.

    When the sidecar carries a content checksum (``verify=True``), the
    snapshot bytes are verified before unpacking; a mismatch raises
    ``repro.checkpoint.io.ChecksumError``. Legacy snapshots without a
    recorded checksum load unchecked."""
    meta = read_meta(path)
    want = meta.get("checksum") if verify else None
    tree = load_pytree(path, _state_tree(template),
                       optional_prefixes=("scale/",),
                       expected_checksum=want)
    return TrainState(**tree)


def checkpoint_workers(meta: Dict[str, Any]) -> Optional[int]:
    """Worker count recorded in a phase-2 snapshot's sidecar meta, or None
    for pre-elastic snapshots (which implicitly match the resuming config)."""
    n = meta.get("n_workers")
    return int(n) if n is not None else None


def shrink_worker_axis(state: TrainState, n_workers: int) -> TrainState:
    """Keep the first ``n_workers`` workers of a phase-2 stacked state.

    Worker-count-aware resume: a checkpoint written by a W-worker run may
    be resumed by a run configured for W' < W workers (an elastic
    deployment that lost hosts) — the surviving workers keep their exact
    trajectories; the dropped tail is discarded. Growing the ensemble
    (W' > W) is refused: freshly cloned workers would share a trajectory
    with an existing one, which breaks the independence the phase-2
    average relies on — restart phase 2 from ``phase1_final`` instead."""
    ckpt_w = int(np.asarray(state.step).reshape(-1).shape[0])
    if n_workers == ckpt_w:
        return state
    if n_workers > ckpt_w:
        raise ValueError(
            f"cannot resume a {ckpt_w}-worker phase-2 checkpoint with "
            f"n_workers={n_workers}: cloned workers would not be "
            f"independent. Shrinking (n_workers <= {ckpt_w}) is supported; "
            f"to grow the ensemble, restart phase 2 from phase1_final.")
    import jax
    return jax.tree_util.tree_map(lambda a: a[:n_workers], state)


def take_worker_axis(state: TrainState, positions) -> TrainState:
    """Keep the stacked-state rows at ``positions`` (any subset, any
    order-preserving selection) — the general form of the elastic shrink.
    A prefix selection routes through ``shrink_worker_axis`` (the audited
    resume path, including its refusal to grow); mid-ensemble losses
    gather the surviving rows. Each kept worker's trajectory is untouched:
    the row is moved, never mixed."""
    positions = [int(p) for p in positions]
    ckpt_w = int(np.asarray(state.step).reshape(-1).shape[0])
    if any(p < 0 or p >= ckpt_w for p in positions):
        raise ValueError(f"worker positions {positions} out of range for a "
                         f"{ckpt_w}-worker stacked state")
    if len(set(positions)) != len(positions):
        raise ValueError(f"duplicate worker positions: {positions}")
    if positions == list(range(len(positions))):
        return shrink_worker_axis(state, len(positions))
    import jax
    import jax.numpy as jnp
    sel = jnp.asarray(positions, jnp.int32)
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a)[sel], state)


# marker key in the dict read_meta returns for a sidecar that EXISTS but
# does not parse (mid-write kill before checksums landed, disk damage):
# such a snapshot is unverifiable and resume-point scans skip it when a
# verified alternative exists. A MISSING sidecar stays the legacy "no
# metadata" case ({}), still accepted.
SIDECAR_CORRUPT = "_sidecar_corrupt"


def read_meta(path: str) -> Dict[str, Any]:
    try:
        with open(path + ".json") as f:
            meta = json.load(f)
    except OSError:
        return {}
    except json.JSONDecodeError as e:
        warnings.warn(f"unreadable checkpoint sidecar {path}.json ({e}); "
                      f"treating the snapshot as unverifiable",
                      RuntimeWarning, stacklevel=2)
        return {SIDECAR_CORRUPT: True}
    if not isinstance(meta, dict):
        warnings.warn(f"checkpoint sidecar {path}.json is not a JSON "
                      f"object; treating the snapshot as unverifiable",
                      RuntimeWarning, stacklevel=2)
        return {SIDECAR_CORRUPT: True}
    return meta


def verify_snapshot(path: str, meta: Optional[Dict[str, Any]] = None) -> bool:
    """Whether a snapshot's bytes are trustworthy enough to restore from.

    * corrupt sidecar → False (the snapshot cannot be tied to a checksum);
    * sidecar with a checksum → recompute over the file bytes and compare;
    * legacy snapshot (no sidecar / no checksum key) → accept if the
      msgpack payload at least unpacks (catches truncation, not bit flips).
    """
    if meta is None:
        meta = read_meta(path)
    if meta.get(SIDECAR_CORRUPT):
        return False
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return False
    want = meta.get("checksum")
    if want is not None:
        return checksum_bytes(data) == want
    return payload_intact(data)


def list_checkpoints(directory: str) -> List[Dict[str, Any]]:
    """All snapshots in ``directory`` as dicts {path, tag, step, meta}."""
    if not directory or not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        m = _FILE_RE.match(name)
        if not m:
            continue
        path = os.path.join(directory, name)
        out.append({"path": path, "tag": m.group(1),
                    "step": int(m.group(2)), "meta": read_meta(path)})
    return out


def find_resume_point(directory: str) -> Optional[Dict[str, Any]]:
    """The snapshot a resumed run should restart from, or None.

    Highest (tag priority, step): the newest phase2 snapshot if any, else
    phase1_final, else the newest mid-phase-1 snapshot. Candidates that
    fail ``verify_snapshot`` (corrupt/truncated bytes, unparseable sidecar)
    are skipped with a warning and the previous good snapshot wins — a
    damaged latest checkpoint costs the steps since the one before it,
    not the run.
    """
    ckpts = list_checkpoints(directory)
    for c in sorted(ckpts, key=lambda c: (_TAG_ORDER[c["tag"]], c["step"]),
                    reverse=True):
        if verify_snapshot(c["path"], c["meta"]):
            return c
        warnings.warn(f"skipping corrupt checkpoint {c['path']} — falling "
                      f"back to the previous verified snapshot",
                      RuntimeWarning, stacklevel=2)
    return None


def publish_path(directory: str, generation: int, step: int) -> str:
    return os.path.join(
        directory, f"publish-gen{generation:08d}-step{step:08d}.msgpack")


def save_publish(directory: str, generation: int, step: int, params,
                 meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write a publishable averaged-params snapshot.

    Same kill-anywhere contract as ``save_train_state``: the sidecar goes
    first, then the snapshot, each via write-then-rename — the ``.msgpack``
    is what ``find_latest_publish`` keys off, so a crash between the two
    writes leaves at worst a stray sidecar, never a loadable torn
    generation."""
    os.makedirs(directory, exist_ok=True)
    path = publish_path(directory, generation, step)
    atomic_write(path + ".json",
                 json.dumps(dict(meta or {}, generation=generation,
                                 step=step,
                                 checksum=checksum_bytes(
                                     pack_pytree(params))),
                            indent=1).encode())
    save_pytree(path, params)
    return path


def list_publishes(directory: str) -> List[Dict[str, Any]]:
    """Complete publish snapshots in ``directory`` as
    {path, generation, step, meta}, ordered by generation."""
    if not directory or not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        m = _PUBLISH_RE.match(name)
        if not m:
            continue
        path = os.path.join(directory, name)
        out.append({"path": path, "generation": int(m.group(1)),
                    "step": int(m.group(2)), "meta": read_meta(path)})
    return sorted(out, key=lambda p: p["generation"])


def find_latest_publish(directory: str) -> Optional[Dict[str, Any]]:
    """Newest verified publish snapshot, or None. Atomic renames guarantee
    any listed ``.msgpack`` is complete as WRITTEN — a publisher killed
    mid-write is simply not visible yet — but out-of-band damage (bit rot,
    a torn copy between hosts) can still corrupt a landed file, so each
    candidate is checksum-verified newest-first and a corrupt generation
    falls back to the previous good one with a warning."""
    for pub in reversed(list_publishes(directory)):
        if verify_snapshot(pub["path"], pub["meta"]):
            return pub
        warnings.warn(f"skipping corrupt publish snapshot {pub['path']} — "
                      f"falling back to the previous generation",
                      RuntimeWarning, stacklevel=2)
    return None


def load_publish(path: str, template) -> Any:
    """Restore a published parameter tree into ``template``'s structure."""
    return load_pytree(path, template)


class Checkpointer:
    """Periodic epoch-aligned snapshots of a TrainState.

    ``every`` is a step count; because the phase engine only surfaces state
    at epoch-chunk boundaries, a snapshot is written at the first boundary
    that is >= ``every`` steps past the previous snapshot (so any
    ``every`` produces a usable cadence; a multiple of steps_per_epoch
    makes it exact). ``keep`` bounds snapshots retained per rolling tag;
    ``phase1_final`` is never pruned (phase-2 resume needs it).
    """

    def __init__(self, directory: str, every: int = 0, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        # seed the cadence from snapshots already on disk: a RESUMED run
        # must not re-snapshot at its first boundary regardless of how far
        # it is from the last durable step
        self._last_saved: Dict[str, int] = {}
        # paths this process wrote (and therefore knows are good) — lets
        # _prune's last-good guard skip re-reading them from disk
        self._verified: set = set()
        if directory:
            os.makedirs(directory, exist_ok=True)
            for c in list_checkpoints(directory):
                self._last_saved[c["tag"]] = max(
                    self._last_saved.get(c["tag"], 0), c["step"])

    def _path(self, tag: str, step: int) -> str:
        return os.path.join(self.directory, f"{tag}-step{step:08d}.msgpack")

    def save(self, tag: str, state: TrainState,
             meta: Optional[Dict[str, Any]] = None) -> str:
        step = state_step(state)
        path = self._path(tag, step)
        meta = dict(meta or {}, tag=tag, step=step)
        # stamp the TRUE worker count from the state's leading axis: after
        # an elastic mid-phase shrink the caller's static n_workers is
        # stale, and a later resume would build a wrong-sized template
        step_arr = np.asarray(state.step)
        if step_arr.ndim >= 1:
            meta["n_workers"] = int(step_arr.shape[0])
        save_train_state(path, state, meta)
        self._last_saved[tag] = step
        self._verified.add(path)
        self._prune(tag)
        return path

    def maybe_save(self, tag: str, state: TrainState,
                   meta: Optional[Dict[str, Any]] = None) -> Optional[str]:
        if self.every <= 0:
            return None
        step = state_step(state)
        if step <= 0 or step - self._last_saved.get(tag, 0) < self.every:
            return None
        return self.save(tag, state, meta)

    def _good(self, entry: Dict[str, Any]) -> bool:
        return (entry["path"] in self._verified
                or verify_snapshot(entry["path"], entry["meta"]))

    def _prune(self, tag: str) -> None:
        if tag == "phase1_final" or self.keep <= 0:
            return
        mine = [c for c in list_checkpoints(self.directory)
                if c["tag"] == tag]
        stale, kept = mine[:-self.keep], mine[-self.keep:]
        # never delete the last verified-good snapshot: if nothing in the
        # kept window verifies (e.g. the newest files were damaged on
        # disk), spare the newest good one among the would-be-pruned so a
        # resume always has somewhere to fall back to. Newest-first so the
        # just-written snapshot (cached in _verified) short-circuits the
        # scan without touching disk.
        if stale and not any(self._good(c) for c in reversed(kept)):
            for c in reversed(stale):
                if self._good(c):
                    stale.remove(c)
                    break
        for entry in stale:
            for p in (entry["path"], entry["path"] + ".json"):
                try:
                    os.remove(p)
                except OSError:
                    pass
