"""Msgpack pytree checkpointing (no orbax in this environment).

Stores a flat {path: (dtype, shape, raw bytes)} map plus the treedef repr;
round-trips arbitrary nested dict/list pytrees of arrays and scalars.
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class ChecksumError(ValueError):
    """Snapshot bytes do not match the checksum recorded in their sidecar
    (bit rot, torn copy, or out-of-band truncation)."""


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def atomic_write(path: str, data: bytes) -> None:
    """Write-then-rename so a kill mid-write can never leave a truncated
    file at ``path`` — the resume contract is 'kill at any point'. The temp
    name starts with '.' so directory scans (checkpoint.state._FILE_RE)
    never match a partial file."""
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, "." + os.path.basename(path) + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def pack_pytree(tree: Any) -> bytes:
    """The exact byte payload ``save_pytree`` writes — exposed so callers
    can checksum the content that will land on disk (msgpack of the same
    flat map is deterministic, so packing twice yields identical bytes)."""
    return msgpack.packb(_flatten(tree), use_bin_type=True)


def checksum_bytes(data: bytes) -> str:
    """Content checksum for snapshot payloads, in sidecar string form."""
    return f"crc32:{zlib.crc32(data) & 0xFFFFFFFF:08x}"


def payload_intact(data: bytes) -> bool:
    """Best-effort integrity probe for LEGACY payloads with no recorded
    checksum: a truncated msgpack stream fails to unpack. Cannot detect a
    same-length bit flip — that needs the checksum sidecar."""
    try:
        msgpack.unpackb(data, raw=False)
    except Exception:
        return False
    return True


def save_pytree(path: str, tree: Any) -> None:
    payload = _flatten(tree)
    atomic_write(path, msgpack.packb(payload, use_bin_type=True))


def load_pytree(path: str, template: Any, optional_prefixes: tuple = (),
                expected_checksum: Optional[str] = None):
    """Restore into the structure of ``template`` (values are replaced).

    Leaves whose key starts with one of ``optional_prefixes`` fall back to
    the template's value when the snapshot predates them (forward compat
    for additive TrainState fields — e.g. the loss-scale state); all other
    missing leaves stay a hard error.

    With ``expected_checksum`` (the sidecar's recorded checksum), the raw
    bytes are verified BEFORE unpacking; a mismatch raises
    ``ChecksumError`` rather than whatever a corrupt msgpack stream would.
    """
    with open(path, "rb") as f:
        raw = f.read()
    if expected_checksum is not None:
        got = checksum_bytes(raw)
        if got != expected_checksum:
            raise ChecksumError(
                f"checkpoint {path} is corrupt: content checksum {got} != "
                f"recorded {expected_checksum}")
    payload = msgpack.unpackb(raw, raw=False)

    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    new_leaves = []
    for pth, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        if key not in payload:
            if key.startswith(optional_prefixes or ()):
                new_leaves.append(leaf)
                continue
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if tuple(rec["shape"]) != want:
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(rec['shape'])} "
                f"but the template expects {want} — was the config (e.g. "
                f"n_workers, model size) changed between save and resume?")
        # np.frombuffer returns a READ-ONLY view into the msgpack payload;
        # copy before handing it to jnp so a later donation of the restored
        # array can never alias (or try to mutate) the checkpoint buffer.
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"]).copy()
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
