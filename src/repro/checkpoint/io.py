"""Msgpack pytree checkpointing (no orbax in this environment).

Stores a flat {path: (dtype, shape, raw bytes)} map plus the treedef repr;
round-trips arbitrary nested dict/list pytrees of arrays and scalars.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = _flatten(tree)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_pytree(path: str, template: Any):
    """Restore into the structure of ``template`` (values are replaced)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)

    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    new_leaves = []
    for pth, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
