"""Sequential SWA baseline (Izmailov et al. 2018) for the Table-4
comparison: cyclic learning rate, one model sampled at each cycle boundary,
streaming average (swa_avg kernel path on TPU), BN recompute at the end.
"""
from __future__ import annotations

import time
from typing import Dict

import jax

from repro.configs.base import SWAConfig
from repro.core.averaging import StreamingAverage
from repro.core.schedules import schedule_fn as make_schedule
from repro.data.pipeline import Loader
from repro.train.precision import default_scale_state


class SWA:
    def __init__(self, adapter, cfg: SWAConfig, train_arrays: Dict,
                 test_loader: Loader):
        self.adapter = adapter
        self.cfg = cfg
        self.train_arrays = train_arrays
        self.test_loader = test_loader

    def run(self, bundle, opt_state=None) -> Dict:
        """Starts from ``bundle`` (fresh init, a large-batch model, or the
        small-batch optimum — the three rows of Table 4)."""
        cfg = self.cfg
        adapter = self.adapter
        loader = Loader(self.train_arrays, cfg.batch_size, seed=cfg.seed)
        sched = make_schedule(cfg.schedule)
        step_fn = jax.jit(adapter.make_train_step(sched),
                          donate_argnums=(0, 1))
        opt_state = opt_state if opt_state is not None \
            else adapter.init_opt(bundle)
        scale = default_scale_state()   # SWA baseline trains plain f32

        t0 = time.perf_counter()
        avg = StreamingAverage()
        total_steps = cfg.n_samples * cfg.cycle_steps
        for step in range(total_steps):
            batch = loader.batch(step)
            bundle, opt_state, scale, metrics = step_fn(
                bundle, opt_state, batch, step, scale)
            if (step + 1) % cfg.cycle_steps == 0:
                avg.add(bundle["params"])
        last_acc = adapter.eval_accuracy(bundle, self.test_loader)
        final = adapter.finalize(avg.value(), loader)
        t1 = time.perf_counter()
        return {
            "before_avg_test_acc": last_acc,
            "after_avg_test_acc": adapter.eval_accuracy(final,
                                                        self.test_loader),
            "time": t1 - t0,
            "n_samples": avg.n,
            "final_bundle": final,
            "last_bundle": bundle,
        }
