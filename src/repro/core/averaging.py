"""Phase 3: weight averaging + batch-norm statistic recomputation.

Algorithm 1, lines 27-28 of the paper:
    θ̂ ← (1/W) Σ θ_w ;  recompute BN statistics for θ̂.

Averaging comes in three forms:
  * ``average_stacked`` — mean over the leading worker axis (phase 3 proper;
    on the TPU mesh this is a `pmean` over the `worker` axis, emitted by
    GSPMD from the jnp.mean below);
  * ``StreamingAverage`` — running mean folding one model at a time (the SWA
    baseline and multi-sample SWAP variants; `swa_avg` Pallas kernel on TPU);
  * ``ElasticAverage`` — the deadline-gated elastic variant: the phase-3
    average is computed from whichever workers REPORT within a deadline
    (each report folds online into a ``StreamingAverage``; a per-worker
    liveness mask records who made it), with a straggler timeout that backs
    off while fewer than ``min_workers`` reported — so a dead or slow
    worker shrinks the ensemble instead of stalling the run (elastic /
    asynchronous averaging per Ajroldi et al. "When, Where and Why to
    Average Weights?"; knobs surface on ``repro.dist.DistConfig``).
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.swa_avg import running_average_tree


def average_stacked(stacked_params):
    """Mean over the leading (worker) axis of every leaf."""
    return jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0),
                                  stacked_params)


def average_list(params_list):
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params_list)
    return average_stacked(stacked)


class StreamingAverage:
    """Numerically-stable running mean of parameter pytrees.

    ``impl`` follows repro.kernels.dispatch: "auto" (default) resolves to
    the fused swa_avg kernel on accelerators (Mosaic on TPU, Triton on
    GPU) and the jnp reference on CPU; "pallas"/"mosaic"/"triton" force a
    lowering (interpreter off its native backend)."""

    def __init__(self, impl: str = "auto"):
        self.impl = dispatch.validate_impl(impl, "StreamingAverage.impl")
        self.n = 0
        self.avg = None

    def add(self, params):
        if self.avg is None:
            # jnp.array(copy=True): the caller's buffers may be donated to
            # its next train step — never hold references into them.
            self.avg = jax.tree_util.tree_map(
                lambda a: jnp.array(a, jnp.float32, copy=True), params)
        else:
            # cast to the accumulator dtype BEFORE folding: the first model
            # is accumulated in f32, so later bf16/f16 trees must enter the
            # fold as f32 too — otherwise the kernel and reference paths
            # see different operand dtypes and can disagree
            w = jax.tree_util.tree_map(
                lambda a, acc: jnp.asarray(a, acc.dtype), params, self.avg)
            self.avg = running_average_tree(self.avg, w, float(self.n),
                                            impl=self.impl)
        self.n += 1
        return self.avg

    def value(self):
        if self.avg is None:
            raise ValueError("no models folded in yet")
        return self.avg


class ElasticAverageError(RuntimeError):
    """No usable elastic average: fewer than ``min_workers`` workers
    reported within the fully backed-off deadline."""


class ElasticAverage:
    """Deadline-gated elastic phase-3 averaging with online partial folds.

    Protocol (one averaging round):

      * each worker that finishes phase 2 ``submit``s its parameters with
        its arrival time (seconds since the round opened). Reports that
        land within the CURRENT deadline fold immediately into a running
        ``StreamingAverage`` — the partial average is always ready, a late
        worker never forces a re-fold of the early ones;
      * the per-worker liveness ``mask`` records who made the average;
      * straggler timeout with backoff: while fewer than ``min_workers``
        workers reported, the deadline extends by ``backoff`` (up to
        ``max_extensions`` times) instead of failing — a slow-but-alive
        quorum is preferred over no average;
      * ``value()`` returns ``(avg_params, mask)`` once at least
        ``min_workers`` reported, and raises ``ElasticAverageError`` when
        every worker blew the fully backed-off deadline.

    ``collect(reports)`` drives a whole round from
    ``(worker, params, arrival_s)`` tuples — the path the SWAP controller
    uses with simulated arrivals, and the multi-host driver uses with real
    report timestamps (arrival order, extensions, and the mask come out
    identical either way because folds are replayed in arrival order).

    The knobs mirror ``repro.dist.DistConfig``: ``elastic_deadline_s``,
    ``elastic_backoff``, ``elastic_max_extensions``, ``elastic_min_workers``.
    """

    def __init__(self, n_workers: int, deadline_s: float, *,
                 backoff: float = 2.0, max_extensions: int = 2,
                 min_workers: int = 1, impl: str = "auto"):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if deadline_s <= 0:
            raise ValueError("ElasticAverage needs deadline_s > 0 (use "
                             "average_stacked for the strict barrier)")
        if backoff < 1.0:
            raise ValueError("backoff must be >= 1 (deadlines never shrink)")
        if not (1 <= min_workers <= n_workers):
            raise ValueError(f"min_workers must be in [1, {n_workers}], "
                             f"got {min_workers}")
        self.n_workers = n_workers
        self.deadline_s = float(deadline_s)
        self.backoff = float(backoff)
        self.max_extensions = int(max_extensions)
        self.min_workers = int(min_workers)
        self.mask = np.zeros(n_workers, dtype=bool)
        self.extensions_used = 0
        self.stragglers: List[Tuple[int, float]] = []  # (worker, arrival_s)
        self._stream = StreamingAverage(impl)

    @property
    def deadline(self) -> float:
        """The current (possibly backed-off) deadline in seconds."""
        return self.deadline_s * self.backoff ** self.extensions_used

    @property
    def n_live(self) -> int:
        return int(self.mask.sum())

    def extend(self) -> bool:
        """Back off the deadline once; False when extensions are spent."""
        if self.extensions_used >= self.max_extensions:
            return False
        self.extensions_used += 1
        return True

    def submit(self, worker: int, params, arrival_s: float) -> bool:
        """Fold one worker's report if it beat the current deadline.
        Returns whether it was folded; a missed deadline records the
        worker as a straggler (its parameters are NOT held)."""
        if not (0 <= worker < self.n_workers):
            raise ValueError(f"worker {worker} out of range "
                             f"[0, {self.n_workers})")
        if self.mask[worker]:
            raise ValueError(f"worker {worker} already reported this round")
        if arrival_s > self.deadline:
            self.stragglers.append((worker, float(arrival_s)))
            return False
        self._stream.add(params)
        self.mask[worker] = True
        return True

    def value(self):
        """(averaged params, liveness mask). Raises ``ElasticAverageError``
        below the ``min_workers`` quorum."""
        if self.n_live < self.min_workers:
            raise ElasticAverageError(
                f"elastic average has {self.n_live}/{self.n_workers} "
                f"workers after {self.extensions_used} deadline "
                f"extension(s) (deadline {self.deadline:g}s, quorum "
                f"{self.min_workers}); stragglers: "
                f"{[(w, round(t, 3)) for w, t in self.stragglers]}")
        return self._stream.value(), self.mask.copy()

    def collect(self, reports: Iterable[Tuple[int, object, float]]):
        """Run a whole round: fold ``(worker, params, arrival_s)`` reports
        in arrival order, backing off the deadline whenever a report is
        late while the quorum is unmet. Workers that never report pass
        ``arrival_s=float('inf')`` (or are simply absent). Returns
        ``value()``."""
        for worker, params, arrival in sorted(reports, key=lambda r: r[2]):
            # a late report only extends the deadline while the quorum is
            # short — once min_workers reported, the round is closeable and
            # stragglers are dropped rather than waited for
            while (arrival > self.deadline
                   and self.n_live < self.min_workers and self.extend()):
                pass
            self.submit(worker, params, arrival)
        return self.value()


def elastic_average_stacked(stacked_params, dist, worker_arrivals=None,
                            impl: str = "auto"):
    """Elastic phase-3 average of an engine-stacked parameter tree.

    Splits the leading worker axis into per-worker reports and folds them
    through ``ElasticAverage`` under ``dist``'s elastic knobs
    (``repro.dist.DistConfig``). ``worker_arrivals`` gives each worker's
    report time in seconds (None = every worker reports instantly;
    ``float('inf')`` marks a lost worker). Returns
    ``(avg_params, liveness_mask)``.

    The in-process engine finishes all workers in lockstep, so arrivals
    here are the *simulation* surface (lost-worker drills, tests, the
    ``--lost-workers`` launcher flag); the multi-host path feeds real
    report timestamps through ``ElasticAverage.collect`` directly.
    """
    n = int(jax.tree_util.tree_leaves(stacked_params)[0].shape[0])
    if worker_arrivals is None:
        worker_arrivals = [0.0] * n
    if len(worker_arrivals) != n:
        raise ValueError(f"worker_arrivals has {len(worker_arrivals)} "
                         f"entries for {n} workers")
    ea = ElasticAverage(
        n, dist.elastic_deadline_s, backoff=dist.elastic_backoff,
        max_extensions=dist.elastic_max_extensions,
        min_workers=dist.elastic_min_workers, impl=impl)
    return ea.collect(
        (w, jax.tree_util.tree_map(lambda a: a[w], stacked_params),
         float(worker_arrivals[w]))
        for w in range(n) if not np.isinf(worker_arrivals[w]))


def _batch_count(batch) -> int:
    """Number of samples in a batch: the leading dim of its first array
    leaf (scalar leaves like ``aug_seed`` carry no sample count)."""
    for leaf in jax.tree_util.tree_leaves(batch):
        if getattr(leaf, "ndim", 0) >= 1:
            return int(leaf.shape[0])
    raise ValueError("cannot infer batch size: batch has no array leaves")


def recompute_bn_stats(batch_stats_fn: Callable, params,
                       batches: Iterable) -> dict:
    """One pass over training data producing fresh BN running statistics for
    averaged weights. ``batch_stats_fn(params, batch) -> {layer: {mean,var}}``.
    Aggregates by batch-size-WEIGHTED averaging (paper: 'computing new
    batch-normalization statistics ... through one pass over the data') —
    an unweighted mean would overweight a short final batch's statistics.
    Raises ValueError on an empty iterable: silently returning no state
    would serve a BN model with stale (pre-average) statistics."""
    acc, total = None, 0
    for batch in batches:
        stats = batch_stats_fn(params, batch)
        bs = _batch_count(batch)
        weighted = jax.tree_util.tree_map(
            lambda x: x * jnp.float32(bs), stats)
        acc = weighted if acc is None \
            else jax.tree_util.tree_map(jnp.add, acc, weighted)
        total += bs
    if acc is None:
        raise ValueError(
            "recompute_bn_stats received no batches — BN statistics need at "
            "least one pass batch (was the loader empty?)")
    return jax.tree_util.tree_map(lambda x: x / total, acc)
