"""Phase 3: weight averaging + batch-norm statistic recomputation.

Algorithm 1, lines 27-28 of the paper:
    θ̂ ← (1/W) Σ θ_w ;  recompute BN statistics for θ̂.

Averaging comes in two forms:
  * ``average_stacked`` — mean over the leading worker axis (phase 3 proper;
    on the TPU mesh this is a `pmean` over the `worker` axis, emitted by
    GSPMD from the jnp.mean below);
  * ``StreamingAverage`` — running mean folding one model at a time (the SWA
    baseline and multi-sample SWAP variants; `swa_avg` Pallas kernel on TPU).
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.kernels.swa_avg import running_average_tree


def average_stacked(stacked_params):
    """Mean over the leading (worker) axis of every leaf."""
    return jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0),
                                  stacked_params)


def average_list(params_list):
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params_list)
    return average_stacked(stacked)


class StreamingAverage:
    """Numerically-stable running mean of parameter pytrees.

    ``impl`` follows repro.kernels.dispatch: "auto" (default) resolves to
    the fused swa_avg Pallas kernel on TPU and the jnp reference
    elsewhere; "pallas" forces the kernel (interpreter off-TPU)."""

    def __init__(self, impl: str = "auto"):
        self.impl = impl
        self.n = 0
        self.avg = None

    def add(self, params):
        if self.avg is None:
            # jnp.array(copy=True): the caller's buffers may be donated to
            # its next train step — never hold references into them.
            self.avg = jax.tree_util.tree_map(
                lambda a: jnp.array(a, jnp.float32, copy=True), params)
        else:
            self.avg = running_average_tree(self.avg, params, float(self.n),
                                            impl=self.impl)
        self.n += 1
        return self.avg

    def value(self):
        if self.avg is None:
            raise ValueError("no models folded in yet")
        return self.avg


def recompute_bn_stats(batch_stats_fn: Callable, params,
                       batches: Iterable) -> dict:
    """One pass over training data producing fresh BN running statistics for
    averaged weights. ``batch_stats_fn(params, batch) -> {layer: {mean,var}}``.
    Aggregates by simple averaging over batches (paper: 'computing new
    batch-normalization statistics ... through one pass over the data')."""
    acc, n = None, 0
    for batch in batches:
        stats = batch_stats_fn(params, batch)
        if acc is None:
            acc = jax.tree_util.tree_map(lambda x: x, stats)
        else:
            acc = jax.tree_util.tree_map(jnp.add, acc, stats)
        n += 1
    if acc is None:
        return {}
    return jax.tree_util.tree_map(lambda x: x / n, acc)
