"""Phase 3: weight averaging + batch-norm statistic recomputation.

Algorithm 1, lines 27-28 of the paper:
    θ̂ ← (1/W) Σ θ_w ;  recompute BN statistics for θ̂.

Averaging comes in two forms:
  * ``average_stacked`` — mean over the leading worker axis (phase 3 proper;
    on the TPU mesh this is a `pmean` over the `worker` axis, emitted by
    GSPMD from the jnp.mean below);
  * ``StreamingAverage`` — running mean folding one model at a time (the SWA
    baseline and multi-sample SWAP variants; `swa_avg` Pallas kernel on TPU).
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.swa_avg import running_average_tree


def average_stacked(stacked_params):
    """Mean over the leading (worker) axis of every leaf."""
    return jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0),
                                  stacked_params)


def average_list(params_list):
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params_list)
    return average_stacked(stacked)


class StreamingAverage:
    """Numerically-stable running mean of parameter pytrees.

    ``impl`` follows repro.kernels.dispatch: "auto" (default) resolves to
    the fused swa_avg kernel on accelerators (Mosaic on TPU, Triton on
    GPU) and the jnp reference on CPU; "pallas"/"mosaic"/"triton" force a
    lowering (interpreter off its native backend)."""

    def __init__(self, impl: str = "auto"):
        self.impl = dispatch.validate_impl(impl, "StreamingAverage.impl")
        self.n = 0
        self.avg = None

    def add(self, params):
        if self.avg is None:
            # jnp.array(copy=True): the caller's buffers may be donated to
            # its next train step — never hold references into them.
            self.avg = jax.tree_util.tree_map(
                lambda a: jnp.array(a, jnp.float32, copy=True), params)
        else:
            # cast to the accumulator dtype BEFORE folding: the first model
            # is accumulated in f32, so later bf16/f16 trees must enter the
            # fold as f32 too — otherwise the kernel and reference paths
            # see different operand dtypes and can disagree
            w = jax.tree_util.tree_map(
                lambda a, acc: jnp.asarray(a, acc.dtype), params, self.avg)
            self.avg = running_average_tree(self.avg, w, float(self.n),
                                            impl=self.impl)
        self.n += 1
        return self.avg

    def value(self):
        if self.avg is None:
            raise ValueError("no models folded in yet")
        return self.avg


def _batch_count(batch) -> int:
    """Number of samples in a batch: the leading dim of its first array
    leaf (scalar leaves like ``aug_seed`` carry no sample count)."""
    for leaf in jax.tree_util.tree_leaves(batch):
        if getattr(leaf, "ndim", 0) >= 1:
            return int(leaf.shape[0])
    raise ValueError("cannot infer batch size: batch has no array leaves")


def recompute_bn_stats(batch_stats_fn: Callable, params,
                       batches: Iterable) -> dict:
    """One pass over training data producing fresh BN running statistics for
    averaged weights. ``batch_stats_fn(params, batch) -> {layer: {mean,var}}``.
    Aggregates by batch-size-WEIGHTED averaging (paper: 'computing new
    batch-normalization statistics ... through one pass over the data') —
    an unweighted mean would overweight a short final batch's statistics.
    Raises ValueError on an empty iterable: silently returning no state
    would serve a BN model with stale (pre-average) statistics."""
    acc, total = None, 0
    for batch in batches:
        stats = batch_stats_fn(params, batch)
        bs = _batch_count(batch)
        weighted = jax.tree_util.tree_map(
            lambda x: x * jnp.float32(bs), stats)
        acc = weighted if acc is None \
            else jax.tree_util.tree_map(jnp.add, acc, weighted)
        total += bs
    if acc is None:
        raise ValueError(
            "recompute_bn_stats received no batches — BN statistics need at "
            "least one pass batch (was the loader empty?)")
    return jax.tree_util.tree_map(lambda x: x / total, acc)
