"""Learning-rate schedules.

The paper uses piecewise-linear warmup+decay for phases 1/2 (cifar10-fast
style) and cyclic triangular schedules for SWA sampling (Figure 6). All
schedules are jit-safe functions of a (traced) step index.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ScheduleConfig


def schedule_fn(cfg: ScheduleConfig):
    if cfg.kind == "const":
        return lambda step: jnp.asarray(cfg.peak_lr, jnp.float32)

    if cfg.kind in ("warmup_linear", "warmup_cosine"):
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
            t = (step - cfg.warmup_steps) / jnp.maximum(
                cfg.total_steps - cfg.warmup_steps, 1)
            t = jnp.clip(t, 0.0, 1.0)
            if cfg.kind == "warmup_linear":
                decay = cfg.peak_lr + (cfg.end_lr - cfg.peak_lr) * t
            else:
                decay = cfg.end_lr + 0.5 * (cfg.peak_lr - cfg.end_lr) * (
                    1.0 + jnp.cos(jnp.pi * t))
            return jnp.where(step < cfg.warmup_steps, warm, decay)
        return fn

    if cfg.kind == "cyclic":
        # SWA triangular cycles: start each cycle at peak_lr, decay linearly
        # to min_lr at the cycle end (models sampled at cycle boundaries).
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            c = jnp.maximum(cfg.cycle_steps, 1)
            t = jnp.mod(step, c) / c
            return cfg.peak_lr + (cfg.min_lr - cfg.peak_lr) * t
        return fn

    raise ValueError(f"unknown schedule kind {cfg.kind!r}")
