"""SWAP (Algorithm 1 of the paper) — the three-phase controller.

Phase 1: synchronous large-batch SGD until train accuracy >= τ (EMA over
         batch accuracy, checked at epoch boundaries — the paper uses epoch
         train accuracy; the streaming EMA surfaced once per compiled epoch
         chunk is its engine-native equivalent) or max_steps.
Phase 2: W independent small-batch workers from the common phase-1 model,
         each with its own data ordering — executed as a *worker-axis
         ensemble*: parameters stacked on a leading W axis and the whole
         scanned epoch advanced in one program. On a worker mesh the
         engine lowers SHARDED (``EpochRunner(engine="sharded")``:
         ``vmap(..., spmd_axis_name="worker")`` with in/out shardings
         pinned to ``ensemble_shardings``) so the compiled program has no
         cross-worker collectives and deploys with the worker axis across
         hosts; without a mesh the same chunk runs as the plain-vmap
         oracle. ``repro.dist.DistConfig`` selects mesh + engine.
Phase 3: average the W models; recompute BN statistics (adapter hook).
         With ``DistConfig.elastic_deadline_s > 0`` the average is ELASTIC:
         it folds whichever workers report within the deadline
         (``repro.core.averaging.ElasticAverage`` — online partial folds,
         per-worker liveness mask, straggler backoff), so a lost worker
         shrinks the ensemble instead of stalling the run.

Execution runs on the compiled phase engine (``repro.train.loop``): a
``TrainState`` (bundle, opt_state, step, accuracy EMA, phase tag, rng)
flows through each phase as epoch-sized ``lax.scan`` chunks inside one jit,
with every worker batch gathered in-trace from device-resident data — the
host never builds or stacks batches in the hot loop. Curve collection,
eval, and checkpointing happen between chunks and are timed separately
from training. With ``SWAPConfig.checkpoint_dir``/``checkpoint_every`` set,
periodic snapshots allow ``run(resume=True)`` to restart bit-exactly
mid-phase-1 or mid-phase-2 (see ``repro.checkpoint.state``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.state import (
    Checkpointer, checkpoint_workers, find_resume_point, list_checkpoints,
    load_train_state, shrink_worker_axis, state_step,
)
from repro.configs.base import PhaseConfig, SWAPConfig
from repro.core.averaging import average_stacked, elastic_average_stacked
from repro.core.schedules import schedule_fn as make_schedule
from repro.data.pipeline import Loader
from repro.dist.config import DistConfig, resolve_dist
from repro.dist.sharding import ensemble_shardings
from repro.train.loop import (
    EpochRunner, TrainState, init_train_state, run_phase, stack_train_state,
)
from repro.train.precision import resolve_policy

_PHASE1_SUMMARY_KEYS = ("phase1_steps", "phase1_train_acc", "phase1_time",
                        "phase1_test_acc", "phase1_skipped_steps",
                        "phase1_loss_scale")


def _stack_bundles(bundle, n: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), bundle)


def _engine_unroll(adapter) -> bool:
    """Unroll epoch chunks for conv models on CPU hosts: XLA:CPU runs
    convolutions inside while-loop bodies on a slow non-vectorized path
    (see EpochRunner); everywhere else the while-form scan is right."""
    return getattr(adapter, "kind", "") == "cnn" \
        and jax.default_backend() == "cpu"


class SGDRun:
    """Plain single-model training (phase 1, and the small/large-batch
    baselines of Tables 1-3) on the compiled phase engine: epoch-sized scan
    chunks, EMA early-exit at epoch boundaries."""

    def __init__(self, adapter, phase: PhaseConfig, train_arrays: Dict,
                 seed: int = 0, dist: Optional[DistConfig] = None):
        self.adapter = adapter
        self.phase = phase
        self.dist = dist if dist is not None else DistConfig()
        self.loader = Loader(train_arrays, phase.batch_size, seed=seed,
                             shard=self.dist.data_shard)
        sched = make_schedule(phase.schedule)
        self.policy = resolve_policy(phase.precision, adapter.opt_cfg)
        self.runner = EpochRunner(
            adapter.make_train_step(sched, policy=self.policy,
                                    grad_accum_steps=phase.grad_accum_steps),
            self.loader, phase.accuracy_ema,
            unroll=_engine_unroll(adapter), donate=self.dist.donate_state)

    def init_state(self, bundle, opt_state=None, start_step: int = 0,
                   phase_tag: str = "phase1") -> TrainState:
        opt_state = opt_state if opt_state is not None \
            else self.adapter.init_opt(bundle)
        return init_train_state(bundle, opt_state, step=start_step,
                                phase=phase_tag,
                                scale=self.policy.init_scale_state())

    def run(self, bundle, opt_state=None, start_step: int = 0,
            log: Optional[list] = None, worker: int = 0,
            checkpointer: Optional[Checkpointer] = None,
            tag: str = "phase1"):
        """Returns (bundle, opt_state, steps_taken, acc_ema)."""
        state = self.init_state(bundle, opt_state, start_step)
        res = run_phase(self.runner, state, worker,
                        max_steps=self.phase.max_steps,
                        stop_accuracy=self.phase.stop_accuracy, log=log,
                        checkpointer=checkpointer, tag=tag)
        st = res.state
        return (st.bundle, st.opt_state, res.steps,
                float(np.asarray(st.acc_ema)))


class SWAP:
    """The full three-phase algorithm over an adapter + dataset."""

    def __init__(self, adapter, cfg: SWAPConfig, train_arrays: Dict,
                 test_loader: Loader, mesh=None,
                 dist: Optional[DistConfig] = None, supervisor=None):
        """``dist``: the unified distribution surface
        (``repro.dist.DistConfig``) — mesh geometry, phase-2 engine choice,
        donation policy, elastic-averaging knobs, multi-host layout. With a
        worker mesh, the phase-2 stacked TrainState is placed with its
        leading W axis sharded over ``worker``
        (``dist.sharding.ensemble_shardings``) and the ensemble epoch
        lowers as ONE sharded-jit program that executes as W independent
        per-worker sub-programs — the paper's no-synchronization property,
        checked in HLO by ``assert_no_cross_worker_collectives``. Without a
        mesh the same code runs as a plain single-device vmap.

        ``mesh=`` is the deprecated pre-DistConfig spelling: it still works
        for one release (a DistConfig is derived from the mesh geometry)
        but emits a DeprecationWarning — see ``repro.dist.resolve_dist``.

        ``supervisor``: an optional ``repro.resilience.PhaseSupervisor``.
        With one attached, both phases run under its retry/rollback/
        dead-worker-recovery state machine — a diverging chunk rolls back
        to the last verified checkpoint, and (with a heartbeat monitor on
        the supervisor) a worker that stops beating mid-phase-2 is dropped
        and the phase resumes with the survivors via the elastic shrink
        path. Recovery actions are surfaced in
        ``results["recovery_events"]``."""
        self.adapter = adapter
        self.cfg = cfg
        self.train_arrays = train_arrays
        self.test_loader = test_loader
        self.dist, self.mesh = resolve_dist(dist, mesh, caller="SWAP")
        self.supervisor = supervisor
        if self.dist.n_workers not in (1, cfg.n_workers) \
                and self.dist.mesh_shape:
            raise ValueError(
                f"DistConfig.n_workers={self.dist.n_workers} disagrees with "
                f"SWAPConfig.n_workers={cfg.n_workers}")

    def _place_ensemble(self, tree):
        if self.mesh is None or "worker" not in self.mesh.axis_names:
            return tree
        return jax.device_put(tree, ensemble_shardings(self.mesh, tree))

    # ------------------------------------------------------------------
    # phase 2 state assembly / restore
    # ------------------------------------------------------------------

    def _phase2_init_state(self, bundle, policy,
                           n_workers: Optional[int] = None) -> TrainState:
        """Fresh stacked phase-2 start state. ``n_workers`` overrides the
        configured W when building a TEMPLATE matching a checkpoint written
        by a different-sized run (worker-count-aware resume)."""
        W = n_workers if n_workers is not None else self.cfg.n_workers
        stacked = _stack_bundles(bundle, W)
        opt_stacked = jax.vmap(self.adapter.init_opt)(stacked)
        return stack_train_state(stacked, opt_stacked, W,
                                 seed=self.cfg.seed + 2,
                                 scale=policy.init_scale_state())

    def run(self, key, collect_curves: bool = False,
            resume: bool = False, phase2_hooks: Sequence = (),
            worker_arrivals: Optional[Sequence[float]] = None,
            heartbeats=None, phase2_chunk_filter=None) -> Dict:
        """``phase2_hooks``: extra epoch-boundary hooks for phase 2, each
        called as ``hook(state, steps_done)`` after every compiled chunk
        (the ``run_phase`` hook surface) — e.g.
        ``repro.serve.publish.WeightPublisher.on_epoch``, which folds the
        across-worker mean into a running average and hot-swaps it into
        live serving engines. Hooks run before curve collection.

        ``worker_arrivals``: per-worker phase-2 report times in seconds for
        ELASTIC phase 3 (``DistConfig.elastic_deadline_s > 0``) —
        ``float('inf')`` marks a lost worker, None means everyone reports
        instantly. The in-process engine finishes workers in lockstep, so
        this is the simulation surface (the ``--lost-workers`` launcher
        flag, tests); multi-host drivers feed real timestamps to
        ``ElasticAverage.collect`` directly.

        ``heartbeats``: an optional ``repro.dist.heartbeat.
        HeartbeatMonitor``. With elastic averaging on, phase-3 arrivals
        come from REAL beacon staleness at averaging time (overriding any
        simulated ``worker_arrivals``) — a stale worker arrives late or
        inf and is backed off / dropped exactly like a simulated one.

        ``phase2_chunk_filter``: a ``(state, metrics) -> (state, metrics)``
        transform applied to what each compiled phase-2 chunk surfaces,
        BEFORE the supervisor's health guard — the fault-injection seam
        (``repro.testing.faults.FaultPlan.chunk_filter``). Requires a
        supervisor: unsupervised runs have no guard to observe the fault,
        so accepting the filter there would silently train on it."""
        cfg = self.cfg
        adapter = self.adapter
        results: Dict = {"phase1_log": [], "phase2_curves": [],
                         "recovery_events": []}

        def _supervised(runner, state, worker, **kw):
            res = self.supervisor.run_phase(runner, state, worker, **kw)
            results["recovery_events"].extend(
                {"kind": e.kind, "attempt": e.attempt, "tag": e.tag,
                 "error": e.error, "restored_step": e.restored_step,
                 "restored_from": e.restored_from,
                 "lost_workers": list(e.lost_workers)} for e in res.events)
            return res

        ckpt = Checkpointer(cfg.checkpoint_dir, cfg.checkpoint_every) \
            if cfg.checkpoint_dir else None
        resume_pt = find_resume_point(cfg.checkpoint_dir) \
            if (resume and cfg.checkpoint_dir) else None

        # ---------------- phase 1: large batch, synchronous --------------
        t0 = time.perf_counter()
        bundle = adapter.init(key)
        p1 = SGDRun(adapter, cfg.phase1, self.train_arrays, seed=cfg.seed,
                    dist=self.dist)
        if resume_pt is not None and resume_pt["tag"] in ("phase1_final",
                                                          "phase2"):
            # phase 1 finished in a previous process: restore its final
            # state + summary metrics from the phase1_final snapshot
            finals = [c for c in list_checkpoints(cfg.checkpoint_dir)
                      if c["tag"] == "phase1_final"]
            if not finals:
                raise ValueError(
                    f"cannot resume {resume_pt['tag']} from "
                    f"{cfg.checkpoint_dir!r}: no phase1_final snapshot")
            state1 = load_train_state(finals[-1]["path"],
                                      p1.init_state(bundle))
            bundle = state1.bundle
            for k in _PHASE1_SUMMARY_KEYS:
                if k in finals[-1]["meta"]:
                    results[k] = finals[-1]["meta"][k]
        else:
            state1 = p1.init_state(bundle)
            prior_t1 = 0.0
            if resume_pt is not None:      # tag == "phase1": mid-phase-1
                state1 = load_train_state(resume_pt["path"], state1)
                # pre-interrupt wall time, so reported phase1_time stays
                # consistent with the cumulative phase1_steps
                prior_t1 = resume_pt["meta"].get("phase1_time", 0.0)
            phase1_kw = dict(
                max_steps=cfg.phase1.max_steps - int(np.asarray(state1.step)),
                stop_accuracy=cfg.phase1.stop_accuracy,
                log=results["phase1_log"], checkpointer=ckpt, tag="phase1",
                checkpoint_meta=lambda tt: {
                    "phase1_time": prior_t1 + time.perf_counter() - t0})
            res1 = _supervised(p1.runner, state1, 0, **phase1_kw) \
                if self.supervisor is not None \
                else run_phase(p1.runner, state1, 0, **phase1_kw)
            state1 = res1.state
            bundle = state1.bundle
            results["phase1_steps"] = int(np.asarray(state1.step))
            results["phase1_train_acc"] = float(np.asarray(state1.acc_ema))
            # loss-scale diagnostics (trivial — 0 skips, scale 1 — for f32)
            results["phase1_skipped_steps"] = int(
                np.asarray(state1.scale.skipped))
            results["phase1_loss_scale"] = float(
                np.asarray(state1.scale.scale))
            results["phase1_time"] = prior_t1 + time.perf_counter() - t0
            results["phase1_test_acc"] = adapter.eval_accuracy(
                bundle, self.test_loader)
            if ckpt is not None:
                ckpt.save("phase1_final", state1,
                          meta={k: results[k] for k in _PHASE1_SUMMARY_KEYS})

        # ---------------- phase 2: independent small-batch workers -------
        W = cfg.n_workers
        loader2 = Loader(self.train_arrays, cfg.phase2.batch_size,
                         seed=cfg.seed + 1)
        # phase 2 defaults to f32 (PhaseConfig.precision): small batches
        # don't need the memory/compute levers, and keeping the refinement
        # trajectories full-precision leaves the paper's averaging /
        # generalization claims untouched
        policy2 = resolve_policy(cfg.phase2.precision, adapter.opt_cfg)
        runner2 = EpochRunner(
            adapter.make_train_step(
                make_schedule(cfg.phase2.schedule), policy=policy2,
                grad_accum_steps=cfg.phase2.grad_accum_steps),
            loader2, cfg.phase2.accuracy_ema, ensemble=True,
            unroll=_engine_unroll(adapter), mesh=self.mesh,
            engine=self.dist.resolved_engine(self.mesh),
            donate=self.dist.donate_state)

        state2 = self._phase2_init_state(bundle, policy2)
        prior_t2 = 0.0
        if resume_pt is not None and resume_pt["tag"] == "phase2":
            # worker-count-aware resume: the snapshot records its W in the
            # sidecar meta; load into a template of THAT size, then shrink
            # the worker axis to this run's W (growing is refused — see
            # repro.checkpoint.state.shrink_worker_axis)
            ckpt_w = checkpoint_workers(resume_pt["meta"])
            template = state2 if ckpt_w in (None, W) \
                else self._phase2_init_state(bundle, policy2, n_workers=ckpt_w)
            state2 = shrink_worker_axis(
                load_train_state(resume_pt["path"], template), W)
            prior_t2 = resume_pt["meta"].get("phase2_train_time", 0.0)
        state2 = self._place_ensemble(state2)
        workers = self._place_ensemble(jnp.arange(W, dtype=jnp.int32))

        # hoisted out of the loop: ONE BN-recompute loader serves every
        # curve point and the final phase-3 finalize
        bn_loader = Loader(self.train_arrays, cfg.bn_recompute_batch_size,
                           seed=cfg.seed)
        hooks = list(phase2_hooks)
        if collect_curves:
            def curve_hook(state: TrainState, done: int):
                avg_now = adapter.finalize(
                    average_stacked(state.bundle["params"]), bn_loader,
                    cfg.bn_recompute_batches)
                # worker count read off the state: a supervised run may
                # have shrunk the ensemble mid-phase
                n_live = int(np.asarray(state.step).reshape(-1).shape[0])
                accs: List[float] = [
                    adapter.eval_accuracy(
                        jax.tree_util.tree_map(lambda a: a[w], state.bundle),
                        self.test_loader, max_batches=2)
                    for w in range(n_live)]
                results["phase2_curves"].append({
                    "step": state_step(state) - 1,
                    "worker_test_accs": accs,
                    "avg_test_acc": adapter.eval_accuracy(
                        avg_now, self.test_loader, max_batches=2)})

            hooks.append(curve_hook)

        phase2_kw = dict(
            max_steps=cfg.phase2.max_steps - state_step(state2),
            chunk_steps=1 if collect_curves else None,
            checkpointer=ckpt, tag="phase2",
            checkpoint_meta=lambda tt: {
                "phase2_train_time": prior_t2 + tt,
                "n_workers": W},
            on_chunk=hooks)
        if self.supervisor is not None:
            res2 = _supervised(runner2, state2, workers,
                               place=self._place_ensemble,
                               chunk_filter=phase2_chunk_filter, **phase2_kw)
            workers = res2.worker
        elif phase2_chunk_filter is not None:
            raise ValueError(
                "phase2_chunk_filter needs a supervisor attached "
                "(SWAP(..., supervisor=...)): without one, no guard "
                "observes the injected fault")
        else:
            res2 = run_phase(runner2, state2, workers, **phase2_kw)
        state2 = res2.state
        # surviving ensemble: the stacked leading axis after any mid-phase
        # recovery shrink, with original worker identities preserved
        W_live = int(np.asarray(state2.step).reshape(-1).shape[0])
        worker_ids = [int(x) for x in np.asarray(workers).reshape(-1)]
        results["phase2_worker_ids"] = worker_ids
        results["phase2_steps"] = state_step(state2)
        # train time only (cumulative across resumes) — curve eval /
        # checkpoint time is reported separately so the paper's speed claim
        # is measured on the hot path
        results["phase2_time"] = prior_t2 + res2.train_time
        results["phase2_eval_time"] = res2.hook_time

        # per-worker test accuracy BEFORE averaging (paper's row 3),
        # indexed by stacked position (worker_ids maps position → identity)
        worker_accs = []
        for w in range(W_live):
            b_w = jax.tree_util.tree_map(lambda a: a[w], state2.bundle)
            worker_accs.append(adapter.eval_accuracy(b_w, self.test_loader))
        results["worker_test_accs"] = worker_accs

        # ---------------- phase 3: average + BN recompute ----------------
        t3 = time.perf_counter()
        if self.dist.elastic:
            # deadline-gated: fold whichever workers reported in time; a
            # lost worker (arrival inf) shrinks the ensemble instead of
            # stalling the run. The liveness mask scopes every averaged-
            # model comparison to the workers that actually contributed.
            # With a heartbeat monitor, arrivals are real beacon staleness
            # at averaging time (staleness-as-lateness) — the simulated
            # worker_arrivals surface only drives heartbeat-less runs.
            if heartbeats is not None:
                worker_arrivals = heartbeats.arrivals(worker_ids)
            elif worker_arrivals is not None and W_live != W \
                    and len(worker_arrivals) == W:
                # simulated arrivals are per ORIGINAL worker id; realign to
                # the survivors' stacked positions
                worker_arrivals = [worker_arrivals[wid] for wid in worker_ids]
            avg_params, live_mask = elastic_average_stacked(
                state2.bundle["params"], self.dist,
                worker_arrivals=worker_arrivals)
        else:
            avg_params = average_stacked(state2.bundle["params"])
            live_mask = np.ones(W_live, dtype=bool)
        # report liveness over the ORIGINAL configured ensemble: a worker
        # dropped by mid-phase recovery is dead, a surviving position maps
        # back to its identity
        full_mask = [False] * W
        for pos, wid in enumerate(worker_ids):
            full_mask[wid] = bool(live_mask[pos])
        results["worker_live_mask"] = full_mask
        results["phase2_live_workers"] = int(sum(full_mask))
        live_accs = [a for a, live in zip(worker_accs, live_mask) if live]
        results["before_avg_test_acc"] = sum(live_accs) / len(live_accs)
        final = adapter.finalize(avg_params, bn_loader,
                                 cfg.bn_recompute_batches)
        t4 = time.perf_counter()
        results["phase3_time"] = t4 - t3
        results["after_avg_test_acc"] = adapter.eval_accuracy(
            final, self.test_loader)
        results["total_time"] = t4 - t0
        results["final_bundle"] = final
        results["stacked_params"] = state2.bundle["params"]
        results["phase1_bundle"] = bundle
        return results
