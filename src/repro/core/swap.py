"""SWAP (Algorithm 1 of the paper) — the three-phase controller.

Phase 1: synchronous large-batch SGD until train accuracy >= τ (EMA over
         batch accuracy — the paper uses epoch train accuracy; EMA is the
         streaming equivalent) or max_steps.
Phase 2: W independent small-batch workers from the common phase-1 model,
         each with its own data ordering — executed as a *worker-axis
         ensemble*: parameters stacked on a leading W axis and the step
         vmapped. On a TPU mesh the W axis is sharded on the `worker` mesh
         axis so the lowered program has no cross-worker collectives; on CPU
         the same code runs as a plain vmap.
Phase 3: average the W models; recompute BN statistics (adapter hook).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import PhaseConfig, SWAPConfig
from repro.core.averaging import average_stacked
from repro.core.schedules import schedule_fn as make_schedule
from repro.data.pipeline import Loader
from repro.dist.sharding import ensemble_shardings


def _stack_bundles(bundle, n: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), bundle)


def _stack_batches(batches: List[Dict]):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)


class SGDRun:
    """Plain single-model training loop (phase 1, and the small/large-batch
    baselines of Tables 1-3)."""

    def __init__(self, adapter, phase: PhaseConfig, train_arrays: Dict,
                 seed: int = 0):
        self.adapter = adapter
        self.phase = phase
        self.loader = Loader(train_arrays, phase.batch_size, seed=seed)
        sched = make_schedule(phase.schedule)
        self.step_fn = jax.jit(adapter.make_train_step(sched),
                               donate_argnums=(0, 1))

    def run(self, bundle, opt_state=None, start_step: int = 0,
            log: Optional[list] = None, worker: int = 0):
        """Returns (bundle, opt_state, steps_taken, acc_ema)."""
        phase = self.phase
        opt_state = opt_state if opt_state is not None \
            else self.adapter.init_opt(bundle)
        ema, beta = 0.0, phase.accuracy_ema
        step = start_step
        for step in range(start_step, start_step + phase.max_steps):
            batch = self.loader.batch(step, worker=worker)
            bundle, opt_state, metrics = self.step_fn(
                bundle, opt_state, batch, step)
            acc = float(metrics["accuracy"])
            ema = beta * ema + (1 - beta) * acc
            if log is not None:
                log.append({"step": step, "accuracy": acc, "ema": ema,
                            "loss": float(metrics["loss"]),
                            "lr": float(metrics["lr"])})
            if ema >= phase.stop_accuracy:
                break
        return bundle, opt_state, step + 1 - start_step, ema


class SWAP:
    """The full three-phase algorithm over an adapter + dataset."""

    def __init__(self, adapter, cfg: SWAPConfig, train_arrays: Dict,
                 test_loader: Loader, mesh=None):
        """``mesh``: optional device mesh with a ``worker`` axis (see
        ``launch.mesh.make_worker_mesh``). When given, the phase-2 stacked
        bundle is placed with its leading W axis sharded over ``worker``
        (``dist.sharding.ensemble_shardings``), so the one vmapped ensemble
        program executes as W independent per-worker sub-programs — the
        paper's no-synchronization property, checked in HLO by
        ``assert_no_cross_worker_collectives``. Without a mesh the same
        code runs as a plain single-device vmap."""
        self.adapter = adapter
        self.cfg = cfg
        self.train_arrays = train_arrays
        self.test_loader = test_loader
        self.mesh = mesh

    def _place_ensemble(self, tree):
        if self.mesh is None or "worker" not in self.mesh.axis_names:
            return tree
        return jax.device_put(tree, ensemble_shardings(self.mesh, tree))

    def run(self, key, collect_curves: bool = False) -> Dict:
        cfg = self.cfg
        adapter = self.adapter
        results: Dict = {"phase1_log": [], "phase2_curves": []}

        # ---------------- phase 1: large batch, synchronous --------------
        t0 = time.perf_counter()
        bundle = adapter.init(key)
        p1 = SGDRun(adapter, cfg.phase1, self.train_arrays, seed=cfg.seed)
        bundle, _, steps1, ema1 = p1.run(bundle, log=results["phase1_log"])
        t1 = time.perf_counter()
        results["phase1_steps"] = steps1
        results["phase1_train_acc"] = ema1
        results["phase1_time"] = t1 - t0
        results["phase1_test_acc"] = adapter.eval_accuracy(
            bundle, self.test_loader)

        # ---------------- phase 2: independent small-batch workers -------
        W = cfg.n_workers
        loader2 = Loader(self.train_arrays, cfg.phase2.batch_size,
                         seed=cfg.seed + 1)
        sched2 = make_schedule(cfg.phase2.schedule)
        raw_step = adapter.make_train_step(sched2)
        ens_step = jax.jit(jax.vmap(raw_step, in_axes=(0, 0, 0, None)),
                           donate_argnums=(0, 1))

        stacked = self._place_ensemble(_stack_bundles(bundle, W))
        opt_stacked = self._place_ensemble(jax.vmap(adapter.init_opt)(stacked))
        for step in range(cfg.phase2.max_steps):
            batches = self._place_ensemble(_stack_batches(
                [loader2.batch(step, worker=w) for w in range(W)]))
            stacked, opt_stacked, metrics = ens_step(
                stacked, opt_stacked, batches, step)
            if collect_curves:
                avg_now = adapter.finalize(
                    average_stacked(stacked["params"]),
                    Loader(self.train_arrays, cfg.bn_recompute_batch_size,
                           seed=cfg.seed), cfg.bn_recompute_batches)
                worker_accs = [
                    adapter.eval_accuracy(
                        jax.tree_util.tree_map(lambda a: a[w], stacked),
                        self.test_loader, max_batches=2)
                    for w in range(W)]
                results["phase2_curves"].append({
                    "step": step, "worker_test_accs": worker_accs,
                    "avg_test_acc": adapter.eval_accuracy(
                        avg_now, self.test_loader, max_batches=2)})
        t2 = time.perf_counter()
        results["phase2_time"] = t2 - t1

        # per-worker test accuracy BEFORE averaging (paper's row 3)
        worker_accs = []
        for w in range(W):
            b_w = jax.tree_util.tree_map(lambda a: a[w], stacked)
            worker_accs.append(adapter.eval_accuracy(b_w, self.test_loader))
        results["worker_test_accs"] = worker_accs
        results["before_avg_test_acc"] = sum(worker_accs) / W

        # ---------------- phase 3: average + BN recompute ----------------
        t3 = time.perf_counter()
        avg_params = average_stacked(stacked["params"])
        bn_loader = Loader(self.train_arrays, cfg.bn_recompute_batch_size,
                           seed=cfg.seed)
        final = adapter.finalize(avg_params, bn_loader,
                                 cfg.bn_recompute_batches)
        t4 = time.perf_counter()
        results["phase3_time"] = t4 - t3
        results["after_avg_test_acc"] = adapter.eval_accuracy(
            final, self.test_loader)
        results["total_time"] = t4 - t0
        results["final_bundle"] = final
        results["stacked_params"] = stacked["params"]
        results["phase1_bundle"] = bundle
        return results
