"""Model adapters: a uniform (init / train_step / eval / finalize) surface
over the two model kinds SWAP trains in this repo:

  * LMAdapter  — any assigned transformer/SSM/MoE architecture (Model);
  * CNNAdapter — the paper-faithful CNN+BatchNorm (phase-3 stat recompute).

A *bundle* is {"params": trainable pytree, "state": non-trainable pytree}
(BN running stats for the CNN; empty for norm-stat-free LMs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.core.averaging import recompute_bn_stats
from repro.data.augment import augment_images
from repro.data.pipeline import Loader
from repro.models import cnn as cnn_mod
from repro.models.model import Model
from repro.optim.api import init_optimizer
from repro.train.precision import (
    PrecisionPolicy, make_precision_train_step,
)
from repro.train.steps import lm_loss_and_metrics


class LMAdapter:
    kind = "lm"

    def __init__(self, cfg: ModelConfig, opt_cfg: OptimizerConfig):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.model = Model(cfg)
        self.opt_init, self._opt_update = init_optimizer(opt_cfg)

    def init(self, key) -> Dict:
        return {"params": self.model.init(key), "state": {}}

    def init_opt(self, bundle):
        return self.opt_init(bundle["params"])

    def make_train_step(self, schedule_fn: Callable,
                        policy: Optional[PrecisionPolicy] = None,
                        grad_accum_steps: int = 1):
        """Engine-facing train step (5-arg precision signature). The LM
        already casts per-matmul from ``ModelConfig.dtype`` (``mdot``), so
        a reduced-precision policy threads its compute dtype through the
        model config — master params stay f32 in HBM and in the optimizer —
        and ``cast_inputs`` stays off (token batches are integers)."""
        model = self.model
        if (policy is not None and policy.casts_compute
                and self.cfg.dtype != policy.compute_dtype):
            model = Model(dataclasses.replace(
                self.cfg, dtype=policy.compute_dtype))

        def loss_with_aux(params, state, batch):
            total, metrics = lm_loss_and_metrics(model, params, batch)
            return total, (metrics, state)

        return make_precision_train_step(
            loss_with_aux, self._opt_update, schedule_fn, policy=policy,
            grad_accum_steps=grad_accum_steps, cast_inputs=False)

    @functools.partial(jax.jit, static_argnums=(0,))
    def _eval_batch(self, bundle, batch):
        _, metrics = lm_loss_and_metrics(self.model, bundle["params"], batch)
        return metrics

    def eval_accuracy(self, bundle, loader: Loader, max_batches: int = 8):
        accs = []
        for i in range(min(max_batches, loader.steps_per_epoch)):
            m = self._eval_batch(bundle, loader.batch(i))
            accs.append(float(m["accuracy"]))
        return sum(accs) / len(accs)

    def finalize(self, params, loader: Loader, n_batches: int = 8) -> Dict:
        """No norm statistics to recompute for RMSNorm/LayerNorm LMs —
        phase 3 reduces to the plain average (executed as a no-op hook)."""
        return {"params": params, "state": {}}


class CNNAdapter:
    kind = "cnn"

    def __init__(self, cfg: ModelConfig, opt_cfg: OptimizerConfig):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.opt_init, self._opt_update = init_optimizer(opt_cfg)

    def init(self, key) -> Dict:
        params, state = cnn_mod.init_cnn(key, self.cfg)
        return {"params": params, "state": state}

    def init_opt(self, bundle):
        return self.opt_init(bundle["params"])

    def _loss(self, params, state, batch):
        images = batch["images"]
        if "aug_seed" in batch:
            images = augment_images(images, batch["aug_seed"])
        # augmentation math runs f32 (jax.random upcasts); re-align the
        # images with the (possibly reduced-precision) params so the conv
        # sees one compute dtype — a no-op for the f32 policy
        images = images.astype(jax.tree_util.tree_leaves(params)[0].dtype)
        logits, new_state = cnn_mod.apply_cnn(params, state, images,
                                              self.cfg, train=True)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, ({"loss": loss, "accuracy": acc,
                       "aux": jnp.zeros((), jnp.float32)}, new_state)

    def make_train_step(self, schedule_fn: Callable,
                        policy: Optional[PrecisionPolicy] = None,
                        grad_accum_steps: int = 1):
        """Engine-facing train step. The CNN has no per-op compute-dtype
        plumbing, so reduced-precision policies pre-cast params + batch
        (``cast_inputs=True``); BN running stats are cast back to their
        master dtype inside the precision step so the scan carry — and
        checkpoints — stay dtype-stable."""
        return make_precision_train_step(
            self._loss, self._opt_update, schedule_fn, policy=policy,
            grad_accum_steps=grad_accum_steps, cast_inputs=True)

    @functools.partial(jax.jit, static_argnums=(0,))
    def _eval_batch(self, bundle, batch):
        logits, _ = cnn_mod.apply_cnn(bundle["params"], bundle["state"],
                                      batch["images"], self.cfg, train=False)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                        .astype(jnp.float32))

    def eval_accuracy(self, bundle, loader: Loader, max_batches: int = 8):
        accs = []
        for i in range(min(max_batches, loader.steps_per_epoch)):
            accs.append(float(self._eval_batch(bundle, loader.batch(i))))
        return sum(accs) / len(accs)

    def finalize(self, params, loader: Loader, n_batches: int = 8) -> Dict:
        """Paper Algorithm 1 line 28: recompute BN statistics for the
        averaged weights with a pass over the training data."""
        stats_fn = jax.jit(lambda p, batch: cnn_mod.cnn_batch_stats(
            p, batch["images"], self.cfg))
        batches = (loader.batch(i) for i in
                   range(min(n_batches, loader.steps_per_epoch)))
        state = recompute_bn_stats(stats_fn, params, batches)
        return {"params": params, "state": state}
