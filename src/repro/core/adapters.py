"""Model adapters: a uniform (init / train_step / eval / finalize) surface
over the two model kinds SWAP trains in this repo:

  * LMAdapter  — any assigned transformer/SSM/MoE architecture (Model);
  * CNNAdapter — the paper-faithful CNN+BatchNorm (phase-3 stat recompute).

A *bundle* is {"params": trainable pytree, "state": non-trainable pytree}
(BN running stats for the CNN; empty for norm-stat-free LMs).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.core.averaging import recompute_bn_stats
from repro.data.augment import augment_images
from repro.data.pipeline import Loader
from repro.models import cnn as cnn_mod
from repro.models.model import Model
from repro.optim.api import init_optimizer
from repro.train.steps import lm_loss_and_metrics


class LMAdapter:
    kind = "lm"

    def __init__(self, cfg: ModelConfig, opt_cfg: OptimizerConfig):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.model = Model(cfg)
        self.opt_init, self._opt_update = init_optimizer(opt_cfg)

    def init(self, key) -> Dict:
        return {"params": self.model.init(key), "state": {}}

    def init_opt(self, bundle):
        return self.opt_init(bundle["params"])

    def make_train_step(self, schedule_fn: Callable):
        def train_step(bundle, opt_state, batch, step):
            def loss_fn(p):
                return lm_loss_and_metrics(self.model, p, batch)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(bundle["params"])
            lr = schedule_fn(step)
            new_p, new_opt = self._opt_update(grads, opt_state,
                                              bundle["params"], lr)
            return {"params": new_p, "state": {}}, new_opt, dict(metrics,
                                                                 lr=lr)
        return train_step

    @functools.partial(jax.jit, static_argnums=(0,))
    def _eval_batch(self, bundle, batch):
        _, metrics = lm_loss_and_metrics(self.model, bundle["params"], batch)
        return metrics

    def eval_accuracy(self, bundle, loader: Loader, max_batches: int = 8):
        accs = []
        for i in range(min(max_batches, loader.steps_per_epoch)):
            m = self._eval_batch(bundle, loader.batch(i))
            accs.append(float(m["accuracy"]))
        return sum(accs) / len(accs)

    def finalize(self, params, loader: Loader, n_batches: int = 8) -> Dict:
        """No norm statistics to recompute for RMSNorm/LayerNorm LMs —
        phase 3 reduces to the plain average (executed as a no-op hook)."""
        return {"params": params, "state": {}}


class CNNAdapter:
    kind = "cnn"

    def __init__(self, cfg: ModelConfig, opt_cfg: OptimizerConfig):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.opt_init, self._opt_update = init_optimizer(opt_cfg)

    def init(self, key) -> Dict:
        params, state = cnn_mod.init_cnn(key, self.cfg)
        return {"params": params, "state": state}

    def init_opt(self, bundle):
        return self.opt_init(bundle["params"])

    def _loss(self, params, state, batch):
        images = batch["images"]
        if "aug_seed" in batch:
            images = augment_images(images, batch["aug_seed"])
        logits, new_state = cnn_mod.apply_cnn(params, state, images,
                                              self.cfg, train=True)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, ({"loss": loss, "accuracy": acc,
                       "aux": jnp.zeros((), jnp.float32)}, new_state)

    def make_train_step(self, schedule_fn: Callable):
        def train_step(bundle, opt_state, batch, step):
            (_, (metrics, new_state)), grads = jax.value_and_grad(
                self._loss, has_aux=True)(bundle["params"], bundle["state"],
                                          batch)
            lr = schedule_fn(step)
            new_p, new_opt = self._opt_update(grads, opt_state,
                                              bundle["params"], lr)
            return ({"params": new_p, "state": new_state}, new_opt,
                    dict(metrics, lr=lr))
        return train_step

    @functools.partial(jax.jit, static_argnums=(0,))
    def _eval_batch(self, bundle, batch):
        logits, _ = cnn_mod.apply_cnn(bundle["params"], bundle["state"],
                                      batch["images"], self.cfg, train=False)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                        .astype(jnp.float32))

    def eval_accuracy(self, bundle, loader: Loader, max_batches: int = 8):
        accs = []
        for i in range(min(max_batches, loader.steps_per_epoch)):
            accs.append(float(self._eval_batch(bundle, loader.batch(i))))
        return sum(accs) / len(accs)

    def finalize(self, params, loader: Loader, n_batches: int = 8) -> Dict:
        """Paper Algorithm 1 line 28: recompute BN statistics for the
        averaged weights with a pass over the training data."""
        stats_fn = jax.jit(lambda p, batch: cnn_mod.cnn_batch_stats(
            p, batch["images"], self.cfg))
        batches = (loader.batch(i) for i in
                   range(min(n_batches, loader.steps_per_epoch)))
        state = recompute_bn_stats(stats_fn, params, batches)
        return {"params": params, "state": state}
