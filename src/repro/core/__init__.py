"""SWAP — the paper's contribution: three-phase large-batch + parallel
weight-averaging training (controller, schedules, averaging, SWA baseline)."""
from repro.core.adapters import CNNAdapter, LMAdapter
from repro.core.averaging import (
    StreamingAverage, average_list, average_stacked, recompute_bn_stats,
)
from repro.core.schedules import schedule_fn
from repro.core.swa import SWA
from repro.core.swap import SWAP, SGDRun
