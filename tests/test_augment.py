"""Train-time augmentation: determinism, cutout, loader integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.augment import augment_images
from repro.data.pipeline import Loader


def test_deterministic_per_seed():
    imgs = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8, 3))
    a1 = augment_images(imgs, jnp.int32(7))
    a2 = augment_images(imgs, jnp.int32(7))
    a3 = augment_images(imgs, jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert np.abs(np.asarray(a1) - np.asarray(a3)).max() > 0


def test_cutout_zeros_a_square():
    imgs = jnp.ones((2, 8, 8, 3))
    out = np.asarray(augment_images(imgs, jnp.int32(3), noise=0.0, cutout=4))
    for b in range(2):
        zeros = (out[b] == 0.0).all(-1)
        assert zeros.sum() == 16       # one 4x4 square per sample


def test_loader_emits_aug_seed():
    loader = Loader({"y": np.arange(32)}, 8, seed=1)
    b0 = loader.batch(0, worker=0)
    b1 = loader.batch(0, worker=1)
    assert "aug_seed" in b0
    assert int(b0["aug_seed"]) != int(b1["aug_seed"])
    assert int(b0["aug_seed"]) == int(loader.batch(0, worker=0)["aug_seed"])
