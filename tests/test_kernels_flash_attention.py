"""Flash-attention kernel: shape/dtype sweeps against the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention


def _mk(B, Sq, Skv, H, KVH, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KVH, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KVH, D), dtype)
    return q, k, v


SHAPES = [
    (1, 16, 16, 4, 4, 16),      # MHA tiny
    (2, 67, 67, 8, 2, 32),      # GQA, ragged seq
    (2, 128, 128, 4, 1, 64),    # kv=1 (gemma-style)
    (1, 33, 129, 4, 2, 24),     # cross-length, odd dims
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("impl", ["reference", "pallas"])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_matches_oracle(shape, impl, causal, window):
    B, Sq, Skv, H, KVH, D = shape
    q, k, v = _mk(B, Sq, Skv, H, KVH, D)
    want = flash_attention(q, k, v, causal=causal, window=window, impl="naive")
    got = flash_attention(q, k, v, causal=causal, window=window, impl=impl,
                          chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_dtypes(dtype, impl):
    q, k, v = _mk(2, 40, 40, 4, 2, 32, dtype=dtype)
    want = flash_attention(q, k, v, impl="naive")
    got = flash_attention(q, k, v, impl=impl, chunk=16)
    assert got.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_decode_offset():
    q, k, v = _mk(2, 1, 64, 8, 4, 32)
    want = flash_attention(q, k, v, causal=True, q_offset=63, impl="naive")
    for impl in ("reference", "pallas"):
        got = flash_attention(q, k, v, causal=True, q_offset=63, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_gradients_match():
    q, k, v = _mk(1, 24, 24, 4, 2, 16)

    def loss(impl):
        return lambda q, k, v: (
            flash_attention(q, k, v, impl=impl, chunk=8) ** 2).sum()

    g_ref = jax.grad(loss("naive"), argnums=(0, 1, 2))(q, k, v)
    for impl in ("reference", "pallas"):
        g = jax.grad(loss(impl), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_ref, g):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("shape,causal,window", [
    ((2, 67, 67, 8, 2, 32), True, 0),     # GQA, ragged, multi-block
    ((1, 40, 40, 4, 1, 16), True, 16),    # kv=1, sliding window
    ((2, 33, 64, 4, 4, 24), False, 0),    # cross-length, non-causal
    ((1, 128, 128, 8, 2, 64), True, 0),   # multiple q AND kv blocks
])
def test_pallas_flash_backward_kernels(shape, causal, window):
    """The true Pallas backward (dQ pass + dK/dV pass with grid-carried
    accumulators and the forward's LSE) vs the oracle's autodiff."""
    B, Sq, Skv, H, KVH, D = shape
    q, k, v = _mk(B, Sq, Skv, H, KVH, D)

    def loss(impl):
        return lambda q, k, v: (flash_attention(
            q, k, v, causal=causal, window=window, impl=impl,
            chunk=16) ** 2).sum()

    g_ref = jax.grad(loss("naive"), argnums=(0, 1, 2))(q, k, v)
    g_pls = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_pls):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-4, rtol=2e-4)


def test_forward_lse_is_correct():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas_fwd
    q, k, v = _mk(2, 32, 32, 4, 2, 16)
    out, lse = flash_attention_pallas_fwd(q, k, v, causal=True)
    # independent lse: logsumexp of masked scaled scores
    G = 2
    qf = (np.asarray(q, np.float32) * 16 ** -0.5).reshape(2, 32, 2, 2, 16)
    s = np.einsum("bqhgd,bkhd->bqhgk", qf, np.asarray(k, np.float32))
    mask = np.tril(np.ones((32, 32), bool))
    s = np.where(mask[None, :, None, None, :], s, -1e30)
    want = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) \
        + s.max(-1)
    want = want.reshape(2, 32, 4)
    np.testing.assert_allclose(np.asarray(lse), want, atol=1e-4, rtol=1e-4)


def test_fully_masked_rows_are_zero():
    # window smaller than gap: early queries see nothing but themselves;
    # fully-masked kv blocks must not poison the output with NaNs.
    q, k, v = _mk(1, 32, 32, 2, 2, 16)
    out = flash_attention(q, k, v, causal=True, window=4, impl="pallas")
    assert bool(jnp.isfinite(out).all())
