"""2-process ``jax.distributed`` smoke (opt-in: REPRO_MULTIHOST=1).

Spawns a real 2-process cluster over a local TCP coordinator — the
``DistConfig.initialize`` path — and runs one SWAP phase-2 worker per
process. XLA's CPU backend cannot execute cross-process computations
("Multiprocess computations aren't implemented on the CPU backend"), so
each worker runs as a HOST-LOCAL program — which is exactly phase 2's
contract (zero cross-worker communication; the sharded-jit lowering is
audited for that separately in test_sharded_engine.py). The processes
exchange results the way a real elastic deployment does: filesystem
reports (params + arrival time) folded by ``ElasticAverage``.

Checks:
  * ``DistConfig.initialize`` brings up the cluster (process_count == 2,
    global devices = sum of local devices);
  * each process's host-local worker chunk is bitwise-identical to the
    same worker computed in a single process (the oracle);
  * the parent's elastic fold over the two reports marks both live.
"""
import os
import socket
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Everything model/data-related lives in one module-level recipe string so
# the child processes and the in-process oracle build EXACTLY the same
# computation from the same seeds.
_WORKER_SRC = '''
import json
import os
import sys

# one local CPU device per process; must be set before jax imports
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"

sys.path.insert(0, os.path.join({repo!r}, "src"))
sys.path.insert(0, os.path.join({repo!r}, "tests"))

from repro.dist.config import DistConfig  # noqa: E402

port, pid, outdir = sys.argv[1], int(sys.argv[2]), sys.argv[3]
dist = DistConfig(n_workers=2, elastic_deadline_s=30.0,
                  coordinator="localhost:" + port,
                  num_processes=2, process_id=pid)
dist.initialize()            # before any jax device query

import jax  # noqa: E402

assert jax.process_count() == 2, jax.process_count()
assert jax.device_count() == 2 * jax.local_device_count()
assert dist.data_shard == (pid, 2)

from repro.checkpoint.io import save_pytree  # noqa: E402
from test_multihost import run_worker_chunk  # noqa: E402

state = run_worker_chunk(pid)
save_pytree(os.path.join(outdir, "worker%d.msgpack" % pid),
            state.bundle["params"])
with open(os.path.join(outdir, "worker%d.json" % pid), "w") as f:
    json.dump({{"worker": pid, "arrival_s": float(pid),
               "step": int(state.step)}}, f)
'''


def _pieces():
    from repro.configs.base import (ModelConfig, OptimizerConfig,
                                    ScheduleConfig)
    from repro.core.adapters import LMAdapter
    from repro.core.schedules import schedule_fn
    from repro.data.pipeline import Loader, make_markov_lm

    cfg = ModelConfig(
        name="tiny-lm", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=32, attention="gqa",
        dtype="float32", remat=False, scan_layers=False)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=128, n_test=32,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, 16, seed=3)
    step_fn = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="const", peak_lr=0.1)))
    return adapter, loader, step_fn


def run_worker_chunk(worker: int):
    """One epoch of phase-2 worker ``worker`` as a host-local program —
    shared by the child processes and the single-process oracle."""
    from repro.train.loop import EpochRunner, init_train_state

    adapter, loader, step_fn = _pieces()
    bundle = adapter.init(jax.random.PRNGKey(1))
    state = init_train_state(bundle, adapter.init_opt(bundle),
                             phase="phase2")
    runner = EpochRunner(step_fn, loader, 0.9, donate=False)
    out, _ = runner.run_chunk(state, jnp.asarray(worker, jnp.int32),
                              loader.steps_per_epoch)
    jax.block_until_ready(out.bundle)
    return out


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@pytest.mark.multihost
def test_two_process_cluster_smoke(tmp_path):
    import json

    from repro.checkpoint.io import load_pytree
    from repro.core.averaging import ElasticAverage

    script = tmp_path / "worker_main.py"
    script.write_text(_WORKER_SRC.format(repo=REPO))
    port = str(_free_port())
    env = {k: v for k, v in os.environ.items()
           if k not in ("XLA_FLAGS", "JAX_PLATFORMS")}

    procs = [subprocess.Popen(
        [sys.executable, str(script), port, str(pid), str(tmp_path)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for pid in range(2)]
    outs = [p.communicate(timeout=300)[0] for p in procs]
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, \
            f"worker process {pid} failed:\n{out}"

    # oracle: the same host-local worker programs in THIS process
    adapter, _, _ = _pieces()
    template = adapter.init(jax.random.PRNGKey(1))["params"]
    reports = []
    for pid in range(2):
        got = load_pytree(str(tmp_path / f"worker{pid}.msgpack"), template)
        want = run_worker_chunk(pid).bundle["params"]
        for a, b in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        meta = json.loads((tmp_path / f"worker{pid}.json").read_text())
        assert meta["worker"] == pid
        reports.append((pid, got, meta["arrival_s"]))

    # the parent folds the filesystem reports exactly like the launcher
    ea = ElasticAverage(2, deadline_s=30.0)
    avg, mask = ea.collect(reports)
    assert mask.tolist() == [True, True]
    for leaf in jax.tree_util.tree_leaves(avg):
        assert np.isfinite(np.asarray(leaf)).all()
