"""Data pipeline: determinism, per-worker ordering, epoch coverage."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import Loader, make_gmm_images, make_markov_lm


def test_markov_deterministic():
    d1 = make_markov_lm(3, vocab=32, n_train=64, n_test=32, seq_len=8)
    d2 = make_markov_lm(3, vocab=32, n_train=64, n_test=32, seq_len=8)
    np.testing.assert_array_equal(d1["train_tokens"], d2["train_tokens"])


def test_markov_labels_are_shifted_tokens():
    d = make_markov_lm(0, vocab=16, n_train=8, n_test=4, seq_len=12)
    np.testing.assert_array_equal(d["train_tokens"][:, 1:],
                                  d["train_labels"][:, :-1])


def test_markov_is_learnable_signal():
    """The chain must be low-entropy enough that the bayes-optimal
    next-token accuracy is well above chance."""
    d = make_markov_lm(0, vocab=32, n_train=512, n_test=128, seq_len=16)
    logits = d["transition_logits"]
    pred = logits.argmax(1)[d["train_tokens"]]
    acc = (pred == d["train_labels"]).mean()
    assert acc > 0.3, acc          # chance is 1/32 ~= 0.03


def test_gmm_shapes_and_balance():
    d = make_gmm_images(0, n_classes=4, image_size=8, n_train=400, n_test=100)
    assert d["train_images"].shape == (400, 8, 8, 3)
    counts = np.bincount(d["train_labels"], minlength=4)
    assert counts.min() > 40       # roughly balanced


class TestLoader:
    def _loader(self, n=64, bs=16, seed=0):
        arrays = {"x": np.arange(n)[:, None].repeat(2, 1),
                  "y": np.arange(n)}
        return Loader(arrays, bs, seed=seed)

    def test_deterministic(self):
        l1, l2 = self._loader(), self._loader()
        for step in (0, 3, 7):
            np.testing.assert_array_equal(np.asarray(l1.batch(step)["y"]),
                                          np.asarray(l2.batch(step)["y"]))

    def test_epoch_covers_all_data_once(self):
        loader = self._loader(n=64, bs=16)
        seen = []
        for step in range(loader.steps_per_epoch):
            seen.extend(np.asarray(loader.batch(step, worker=1)["y"]).tolist())
        assert sorted(seen) == list(range(64))

    def test_workers_get_different_orders(self):
        loader = self._loader()
        b0 = np.asarray(loader.batch(0, worker=0)["y"])
        b1 = np.asarray(loader.batch(0, worker=1)["y"])
        assert not np.array_equal(b0, b1)

    def test_epochs_get_different_orders(self):
        loader = self._loader(n=64, bs=16)
        e0 = np.asarray(loader.batch(0, worker=0)["y"])
        e1 = np.asarray(loader.batch(loader.steps_per_epoch, worker=0)["y"])
        assert not np.array_equal(e0, e1)

    def test_tail_drop_warns_once_and_is_queryable(self):
        """Regression: a batch size that does not divide the dataset used
        to silently shrink every epoch. Construction must warn (once, with
        the dropped count) and expose ``dropped_per_epoch``."""
        import warnings
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            loader = self._loader(n=70, bs=16)
        assert loader.dropped_per_epoch == 70 % 16 == 6
        msgs = [str(w.message) for w in caught
                if "drops" in str(w.message)]
        assert len(msgs) == 1
        assert "6 of 70" in msgs[0]
        # the epoch itself still covers exactly the kept samples, once each
        seen = []
        for step in range(loader.steps_per_epoch):
            seen.extend(np.asarray(loader.batch(step)["y"]).tolist())
        assert len(seen) == len(set(seen)) == 64

    def test_no_tail_no_warning(self):
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # any warning -> test failure
            loader = self._loader(n=64, bs=16)
        assert loader.dropped_per_epoch == 0

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 200), bs=st.integers(1, 8), w=st.integers(0, 5),
           epoch=st.integers(0, 3))
    def test_property_every_epoch_is_a_permutation(self, n, bs, w, epoch):
        """For any (size, batch, worker, epoch): batches within an epoch
        never repeat a sample and each item appears at most once."""
        import warnings
        arrays = {"y": np.arange(n)}
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")    # tail-drop warning expected
            loader = Loader(arrays, bs, seed=1)
        spe = loader.steps_per_epoch
        seen = []
        for s in range(spe):
            seen.extend(np.asarray(
                loader.batch(epoch * spe + s, worker=w)["y"]).tolist())
        assert len(seen) == len(set(seen))
