"""Elastic phase-3 averaging (repro.core.averaging.ElasticAverage):
deadline gating, straggler backoff, liveness masks, quorum failure — and
the end-to-end SWAP contract that a lost worker shrinks the average
instead of stalling or poisoning it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.averaging import (ElasticAverage, ElasticAverageError,
                                  elastic_average_stacked)
from repro.dist.config import DistConfig

INF = float("inf")


def _params(value):
    return {"w": jnp.full((3, 2), value, jnp.float32),
            "b": jnp.full((4,), value * 2, jnp.float32)}


def _stacked(values):
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[_params(v) for v in values])


def _assert_close(tree, expect):
    np.testing.assert_allclose(np.asarray(tree["w"]),
                               np.asarray(expect["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tree["b"]),
                               np.asarray(expect["b"]), rtol=1e-6)


def test_all_on_time_is_plain_mean():
    ea = ElasticAverage(4, deadline_s=10.0)
    for w in range(4):
        assert ea.submit(w, _params(float(w)), arrival_s=1.0)
    avg, mask = ea.value()
    _assert_close(avg, _params(1.5))
    assert mask.all() and mask.shape == (4,)


def test_dropped_worker_shrinks_the_average():
    """A worker that never reports (inf arrival) is excluded: the average
    is the mean of the LIVE workers, and the mask records who made it."""
    stacked = _stacked([0.0, 1.0, 2.0, 9.0])
    dist = DistConfig(n_workers=4, elastic_deadline_s=10.0)
    avg, mask = elastic_average_stacked(
        stacked, dist, worker_arrivals=[0.0, 0.0, 0.0, INF])
    _assert_close(avg, _params(1.0))          # mean of workers 0..2 only
    assert mask.tolist() == [True, True, True, False]


def test_straggler_past_deadline_dropped_once_quorum_met():
    """With the quorum already satisfied, a late report neither folds nor
    extends the deadline — stragglers are dropped, not waited for."""
    stacked = _stacked([1.0, 3.0, 100.0])
    dist = DistConfig(n_workers=3, elastic_deadline_s=5.0,
                      elastic_min_workers=2)
    avg, mask = elastic_average_stacked(
        stacked, dist, worker_arrivals=[1.0, 2.0, 500.0])
    _assert_close(avg, _params(2.0))
    assert mask.tolist() == [True, True, False]


def test_backoff_extends_deadline_while_quorum_short():
    """A late report while the quorum is unmet backs the deadline off
    (deadline_s * backoff**k) until the report fits — a slow-but-alive
    quorum beats no average."""
    stacked = _stacked([1.0, 3.0])
    dist = DistConfig(n_workers=2, elastic_deadline_s=5.0,
                      elastic_backoff=2.0, elastic_max_extensions=2,
                      elastic_min_workers=2)
    # worker 1 arrives at 18s: misses 5s and 10s, fits the 20s deadline
    avg, mask = elastic_average_stacked(
        stacked, dist, worker_arrivals=[1.0, 18.0])
    _assert_close(avg, _params(2.0))
    assert mask.tolist() == [True, True]


def test_late_report_after_quorum_met_does_not_extend_deadline():
    """Extensions exist to reach quorum, not to rescue stragglers: once
    min_workers reported, a late report must neither fold nor consume a
    deadline extension."""
    ea = ElasticAverage(3, deadline_s=5.0, backoff=2.0, max_extensions=2,
                        min_workers=2)
    avg, mask = ea.collect([(0, _params(1.0), 1.0), (1, _params(3.0), 2.0),
                            (2, _params(99.0), 50.0)])
    assert ea.extensions_used == 0           # quorum was met — no backoff
    assert ea.deadline == 5.0
    assert mask.tolist() == [True, True, False]
    _assert_close(avg, _params(2.0))
    assert ea.stragglers == [(2, 50.0)]


def test_exact_deadline_arrival_folds():
    """An arrival exactly AT the deadline is on time (the gate is
    ``arrival > deadline``), so boundary reports are never dropped by a
    strict-inequality off-by-one."""
    ea = ElasticAverage(2, deadline_s=5.0)
    assert ea.submit(0, _params(1.0), 5.0)
    avg, mask = ea.value()
    assert mask.tolist() == [True, False]
    _assert_close(avg, _params(1.0))


def test_all_workers_late_error_reports_extension_count():
    """When every worker blows even the fully backed-off deadline, the
    error must say how far the deadline was extended — the operator's
    first question is whether backoff was exhausted or never configured."""
    ea = ElasticAverage(2, deadline_s=1.0, backoff=2.0, max_extensions=2,
                        min_workers=2)
    with pytest.raises(ElasticAverageError,
                       match=r"0/2 workers after 2 deadline extension"):
        ea.collect([(0, _params(1.0), 99.0), (1, _params(2.0), 99.0)])
    assert ea.extensions_used == 2           # the full budget was spent


def test_all_late_raises():
    ea = ElasticAverage(2, deadline_s=1.0, backoff=2.0, max_extensions=1,
                        min_workers=1)
    with pytest.raises(ElasticAverageError, match="0/2"):
        ea.collect([(0, _params(1.0), 99.0), (1, _params(2.0), 99.0)])


def test_quorum_failure_reports_stragglers():
    ea = ElasticAverage(3, deadline_s=1.0, backoff=2.0, max_extensions=0,
                        min_workers=2)
    ea.submit(0, _params(1.0), 0.5)
    ea.submit(1, _params(2.0), 7.0)           # straggler, recorded
    with pytest.raises(ElasticAverageError, match="1/3"):
        ea.value()


def test_deadline_backoff_schedule():
    ea = ElasticAverage(4, deadline_s=3.0, backoff=2.0, max_extensions=2)
    assert ea.deadline == 3.0
    assert ea.extend() and ea.deadline == 6.0
    assert ea.extend() and ea.deadline == 12.0
    assert not ea.extend() and ea.deadline == 12.0   # extensions spent


def test_submit_validation():
    ea = ElasticAverage(2, deadline_s=10.0)
    ea.submit(0, _params(1.0), 0.0)
    with pytest.raises(ValueError, match="already reported"):
        ea.submit(0, _params(1.0), 0.0)
    with pytest.raises(ValueError, match="out of range"):
        ea.submit(2, _params(1.0), 0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        ElasticAverage(2, deadline_s=0.0)


def test_elastic_average_stacked_arrival_length_validated():
    dist = DistConfig(n_workers=2, elastic_deadline_s=1.0)
    with pytest.raises(ValueError, match="3 entries for 2 workers"):
        elastic_average_stacked(_stacked([1.0, 2.0]), dist,
                                worker_arrivals=[0.0, 0.0, 0.0])


def test_swap_run_with_lost_worker():
    """End-to-end: a 4-worker SWAP run where worker 3 never reports must
    complete, average only the 3 live workers, and report the liveness
    mask + live-worker-only before_avg accuracy."""
    from repro.configs import registry
    from repro.configs.base import (OptimizerConfig, PhaseConfig,
                                    ScheduleConfig, SWAPConfig)
    from repro.core.adapters import LMAdapter
    from repro.core.swap import SWAP
    from repro.data.pipeline import Loader, make_markov_lm

    cfg = registry.get_smoke_config("internlm2-1.8b")
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=128, n_test=64,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    test_loader = Loader({"tokens": data["test_tokens"],
                          "labels": data["test_labels"]}, 32)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    swap_cfg = SWAPConfig(
        n_workers=4,
        phase1=PhaseConfig(batch_size=32, max_steps=4,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.1)),
        phase2=PhaseConfig(batch_size=16, max_steps=2,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.05)),
        bn_recompute_batch_size=64)
    dist = DistConfig(n_workers=4, elastic_deadline_s=30.0)
    res = SWAP(adapter, swap_cfg, train, test_loader, dist=dist).run(
        jax.random.PRNGKey(0), worker_arrivals=[0.0, 0.0, 0.0, INF])

    assert res["worker_live_mask"] == [True, True, True, False]
    assert res["phase2_live_workers"] == 3
    live = res["worker_test_accs"][:3]
    assert res["before_avg_test_acc"] == pytest.approx(sum(live) / 3)
    assert 0.0 <= res["after_avg_test_acc"] <= 1.0


def test_swap_all_workers_live_without_elastic():
    """The non-elastic path still reports a (full) liveness mask, so result
    consumers have one schema."""
    from repro.configs import registry
    from repro.configs.base import (OptimizerConfig, PhaseConfig,
                                    ScheduleConfig, SWAPConfig)
    from repro.core.adapters import LMAdapter
    from repro.core.swap import SWAP
    from repro.data.pipeline import Loader, make_markov_lm

    cfg = registry.get_smoke_config("internlm2-1.8b")
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=64, n_test=32,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    test_loader = Loader({"tokens": data["test_tokens"],
                          "labels": data["test_labels"]}, 32)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    swap_cfg = SWAPConfig(
        n_workers=2,
        phase1=PhaseConfig(batch_size=32, max_steps=2,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.1)),
        phase2=PhaseConfig(batch_size=16, max_steps=2,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.05)),
        bn_recompute_batch_size=32)
    res = SWAP(adapter, swap_cfg, train, test_loader).run(
        jax.random.PRNGKey(0))
    assert res["worker_live_mask"] == [True, True]
    assert res["phase2_live_workers"] == 2
