"""End-to-end SWAP integration: the paper's qualitative claims on synthetic
data, small enough for CI but large enough that the claims are visible."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig,
                                ScheduleConfig, SWAConfig, SWAPConfig)
from repro.core.adapters import CNNAdapter, LMAdapter
from repro.core.swa import SWA
from repro.core.swap import SWAP, SGDRun
from repro.data.pipeline import Loader, make_gmm_images, make_markov_lm


@pytest.fixture(scope="module")
def cnn_setup():
    cfg = registry.get_smoke_config("cifar-cnn")
    data = make_gmm_images(0, n_classes=10, image_size=16, n_train=1024,
                           n_test=512, noise=2.0)
    train = {"images": data["train_images"], "labels": data["train_labels"]}
    test_loader = Loader({"images": data["test_images"],
                          "labels": data["test_labels"]}, 256)
    adapter = CNNAdapter(cfg, OptimizerConfig(kind="sgd"))
    return adapter, train, test_loader


@pytest.fixture(scope="module")
def swap_result(cnn_setup):
    adapter, train, test_loader = cnn_setup
    cfg = SWAPConfig(
        n_workers=4,
        phase1=PhaseConfig(batch_size=512, max_steps=40, stop_accuracy=0.8,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.4,
                                                   warmup_steps=8,
                                                   total_steps=40)),
        phase2=PhaseConfig(batch_size=64, max_steps=30,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.05,
                                                   warmup_steps=0,
                                                   total_steps=30)),
        bn_recompute_batches=4, bn_recompute_batch_size=256)
    return SWAP(adapter, cfg, train, test_loader).run(jax.random.PRNGKey(0))


def test_phases_execute(swap_result):
    r = swap_result
    assert r["phase1_steps"] > 0
    assert len(r["worker_test_accs"]) == 4
    assert 0.0 <= r["after_avg_test_acc"] <= 1.0


def test_averaged_model_at_least_mean_of_workers(swap_result):
    """Figure 1/paper text: 'the averaged model performs consistently better
    than each individual model'. We assert >= mean(workers) - eps to keep
    the test robust at this scale."""
    r = swap_result
    assert r["after_avg_test_acc"] >= r["before_avg_test_acc"] - 0.01


def test_phase3_bn_stats_recomputed(swap_result):
    state = swap_result["final_bundle"]["state"]
    assert state, "CNN must get recomputed BN statistics in phase 3"
    for leaf in jax.tree_util.tree_leaves(state):
        assert np.isfinite(np.asarray(leaf)).all()


def test_phase1_stops_at_accuracy_threshold(cnn_setup):
    adapter, train, test_loader = cnn_setup
    phase = PhaseConfig(batch_size=256, max_steps=200, stop_accuracy=0.30,
                        accuracy_ema=0.5,
                        schedule=ScheduleConfig(kind="const", peak_lr=0.2))
    run = SGDRun(adapter, phase, train)
    bundle = adapter.init(jax.random.PRNGKey(1))
    _, _, steps, ema = run.run(bundle)
    assert steps < 200, "should exit early at the accuracy threshold"
    assert ema >= 0.30


def test_swa_baseline_runs(cnn_setup):
    adapter, train, test_loader = cnn_setup
    cfg = SWAConfig(n_samples=3, cycle_steps=10, batch_size=128,
                    schedule=ScheduleConfig(kind="cyclic", peak_lr=0.1,
                                            min_lr=0.01, cycle_steps=10))
    bundle = adapter.init(jax.random.PRNGKey(0))
    res = SWA(adapter, cfg, train, test_loader).run(bundle)
    assert res["n_samples"] == 3
    assert 0.0 <= res["after_avg_test_acc"] <= 1.0


def test_swap_on_lm_arch():
    """SWAP is architecture-agnostic: run it end-to-end on a transformer."""
    cfg = registry.get_smoke_config("internlm2-1.8b")
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=512, n_test=256,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    test_loader = Loader({"tokens": data["test_tokens"],
                          "labels": data["test_labels"]}, 128)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    swap_cfg = SWAPConfig(
        n_workers=2,
        phase1=PhaseConfig(batch_size=128, max_steps=20,
                           schedule=ScheduleConfig(kind="warmup_linear",
                                                   peak_lr=0.3,
                                                   warmup_steps=5,
                                                   total_steps=20)),
        phase2=PhaseConfig(batch_size=32, max_steps=10,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.02)))
    res = SWAP(adapter, swap_cfg, train, test_loader).run(
        jax.random.PRNGKey(0))
    assert np.isfinite(res["after_avg_test_acc"])
    assert res["phase2_time"] > 0
