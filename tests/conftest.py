import os
import sys

# Multi-device CPU harness: mesh/sharding tests exercise 8 fake host devices
# (worker x data x model splits) instead of a degenerate 1-device mesh. Must
# be set BEFORE jax is first imported. Importing repro.launch.dryrun during
# collection must NOT flip the process to 512 devices (dryrun uses
# setdefault, so the explicit assignment here wins).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import repro.dist  # noqa: E402,F401  (installs the JAX 0.4.37 compat shims)

# The CI image has no hypothesis; install the deterministic stub only when
# the real library is absent (see repro/testing/hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_stub

    sys.modules["hypothesis"] = hypothesis_stub
    sys.modules["hypothesis.strategies"] = hypothesis_stub.strategies

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu: needs a real TPU (Pallas compiled mode, ICI-bandwidth asserts)"
        " — skipped on CPU hosts")
    config.addinivalue_line(
        "markers",
        "gpu: needs a real GPU (compiled Triton lowering; the interpret-"
        "mode equivalence tests run everywhere) — skipped on CPU hosts")
    config.addinivalue_line(
        "markers",
        "multihost: spawns a 2-process jax.distributed cluster (local TCP "
        "coordinator) — opt in with REPRO_MULTIHOST=1 (the CI smoke step "
        "sets it); skipped by default so plain tier-1 runs stay hermetic")


def pytest_collection_modifyitems(config, items):
    backend = jax.default_backend()
    skips = {marker: pytest.mark.skip(
        reason=f"requires a real {marker.upper()}; this host runs the XLA "
               f"{backend.upper()} backend")
        for marker in ("tpu", "gpu") if marker != backend}
    if os.environ.get("REPRO_MULTIHOST") != "1":
        skips["multihost"] = pytest.mark.skip(
            reason="2-process jax.distributed smoke; set REPRO_MULTIHOST=1 "
                   "to run")
    for item in items:
        for marker, skip in skips.items():
            if marker in item.keywords:
                item.add_marker(skip)
