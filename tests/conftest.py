import os

# Tests run on the host's real device view (1 CPU device). Only the dry-run
# entrypoint forces 512 fake devices — importing repro.launch.dryrun during
# pytest collection must NOT flip the whole test process to 512 devices
# (dryrun uses setdefault, so pinning XLA_FLAGS here wins).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=1"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
