"""Backend-aware kernel dispatch: resolution table on the CPU CI backend,
auto == reference on CPU, and forced-pallas StreamingAverage bitwise-equal
to the reference on every leaf shape of a real model bundle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.averaging import StreamingAverage
from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd.ops import ssd_scan
from repro.models.model import Model


def test_resolve_on_cpu_ci_backend():
    """This suite runs on the XLA CPU backend: auto must pick the jnp
    reference (never interpreter-Pallas in a hot path), and forcing
    pallas must flip interpret mode on."""
    assert dispatch.current_backend() == "cpu"
    d = dispatch.resolve("auto")
    assert d.impl == "reference" and d.backend == "cpu"
    d = dispatch.resolve("pallas")
    assert d.impl == "pallas" and d.interpret is True
    assert dispatch.resolve("reference").impl == "reference"
    assert dispatch.resolve("naive").impl == "naive"
    assert dispatch.interpret_default() is True


def test_resolve_on_accelerators():
    """Both accelerator backends compile their native lowering: Mosaic on
    TPU, Triton on GPU — auto resolves to a compiled pallas impl on each.
    (Explicit backend arg — no accelerator needed to check the table.)"""
    for requested in ("auto", "pallas"):
        d = dispatch.resolve(requested, backend="tpu")
        assert d.impl == "pallas" and d.interpret is False, requested
        assert d.variant == "mosaic"
    assert dispatch.interpret_default("tpu") is False

    for requested in ("auto", "pallas"):
        d = dispatch.resolve(requested, backend="gpu")
        assert d.impl == "pallas" and d.interpret is False, requested
        assert d.variant == "triton"
    assert dispatch.resolve("reference", backend="tpu").impl == "reference"
    assert dispatch.resolve("reference", backend="gpu").impl == "reference"


def test_resolve_forced_lowerings():
    """"mosaic"/"triton" force a specific lowering; off its native backend
    the program runs in the Pallas interpreter (CPU CI equivalence tests),
    on it the program compiles."""
    d = dispatch.resolve("triton", backend="cpu")
    assert (d.impl, d.variant, d.interpret) == ("pallas", "triton", True)
    d = dispatch.resolve("triton", backend="gpu")
    assert (d.impl, d.variant, d.interpret) == ("pallas", "triton", False)
    d = dispatch.resolve("mosaic", backend="gpu")
    assert (d.impl, d.variant, d.interpret) == ("pallas", "mosaic", True)
    d = dispatch.resolve("mosaic", backend="tpu")
    assert (d.impl, d.variant, d.interpret) == ("pallas", "mosaic", False)
    # forced "pallas" off-accelerator keeps its historical meaning: the
    # Mosaic program under the interpreter
    d = dispatch.resolve("pallas", backend="cpu")
    assert (d.impl, d.variant, d.interpret) == ("pallas", "mosaic", True)


def test_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown kernel impl"):
        dispatch.resolve("cuda")


def test_auto_is_reference_on_cpu_for_ops():
    """impl="auto" (the config default) must run the exact same path as
    impl="reference" on CPU — bitwise, both ops."""
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (2, 16, 4, 8))
               for i in range(3))
    np.testing.assert_array_equal(
        np.asarray(flash_attention(q, k, v, impl="auto", chunk=8)),
        np.asarray(flash_attention(q, k, v, impl="reference", chunk=8)))

    B, S, H, P, G, N = 2, 32, 4, 8, 2, 16
    x = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 4),
                                           (B, S, H)))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 5), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 6), (B, S, G, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 7), (B, S, G, N))
    ya, sa = ssd_scan(x, dt, A, Bm, Cm, impl="auto", chunk=16)
    yr, sr = ssd_scan(x, dt, A, Bm, Cm, impl="reference", chunk=16)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sr))


def test_streaming_average_pallas_bitwise_on_real_bundle():
    """Forcing impl="pallas" in StreamingAverage must stay BITWISE equal
    to the reference on every leaf shape of a real model bundle — embed
    tables, stacked block weights (3-D/4-D, non-tile-aligned), norm
    scales. The swa_avg kernel divides (never multiplies by a
    reciprocal) precisely so this holds."""
    cfg = registry.get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    p1 = model.init(jax.random.PRNGKey(0))
    p2 = model.init(jax.random.PRNGKey(1))
    p3 = jax.tree_util.tree_map(lambda a: 0.5 * a, p1)

    ref, pal = StreamingAverage(impl="reference"), StreamingAverage(
        impl="pallas")
    for p in (p1, p2, p3):
        ref.add(p)
        pal.add(p)
    flat_r = jax.tree_util.tree_flatten_with_path(ref.value())[0]
    flat_p = jax.tree_util.tree_flatten(pal.value())[0]
    assert len(flat_r) == len(flat_p) > 5
    for (path, leaf_r), leaf_p in zip(flat_r, flat_p):
        np.testing.assert_array_equal(
            np.asarray(leaf_r), np.asarray(leaf_p),
            err_msg=f"leaf {jax.tree_util.keystr(path)} "
                    f"shape {leaf_r.shape}")


def test_streaming_average_default_is_auto():
    assert StreamingAverage().impl == "auto"


@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_streaming_average_bf16_folds_match_f32(impl):
    """Regression: folding bf16 param trees into the f32 accumulator must
    cast BEFORE the running-average op on both impls — the result equals
    averaging the f32 upcasts exactly, and the accumulator stays f32.
    (Previously the mixed-dtype fold hit whatever promotion the chosen
    kernel applied, so reference and pallas could disagree.)"""
    cfg = registry.get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    trees = [jax.tree_util.tree_map(
        lambda a: jnp.asarray(a, jnp.bfloat16),
        model.init(jax.random.PRNGKey(i))) for i in range(3)]
    as_f32 = [jax.tree_util.tree_map(
        lambda a: jnp.asarray(a, jnp.float32), t) for t in trees]

    mixed = StreamingAverage(impl=impl)
    plain = StreamingAverage(impl=impl)
    for bf, f32 in zip(trees, as_f32):
        mixed.add(bf)                    # bf16 folds into f32 accumulator
        plain.add(f32)                   # (first fold seeds it as f32)
    for leaf_m, leaf_p in zip(jax.tree_util.tree_leaves(mixed.value()),
                              jax.tree_util.tree_leaves(plain.value())):
        assert leaf_m.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(leaf_m),
                                      np.asarray(leaf_p))
