"""Per-architecture smoke tests: REDUCED configs (2 layers, d_model<=512,
<=4 experts), one forward + one train step + prefill/decode consistency on
CPU. Shapes and finiteness asserted; the FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ScheduleConfig, replace
from repro.core.schedules import schedule_fn
from repro.models.model import Model
from repro.train.steps import make_lm_train_step

ARCHS = registry.ASSIGNED_ARCHS + registry.BONUS_ARCHS


def _extras(cfg, key, B):
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return extras


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = registry.get_smoke_config(arch)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = model.apply(params, tokens, **_extras(cfg, key, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, key):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(key)
    opt_init, step_fn = make_lm_train_step(
        model, OptimizerConfig(kind="sgd"),
        schedule_fn(ScheduleConfig(kind="const", peak_lr=0.01)))
    opt_state = opt_init(params)
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        **_extras(cfg, key, B),
    }
    new_params, _, metrics = jax.jit(step_fn)(params, opt_state, batch, 0)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    # everything stayed finite
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """decode(prefill(t[:S]), t[S:]) must reproduce apply(t) logits.
    MoE archs use a no-drop capacity factor so token dropping can't differ
    between the full and incremental paths."""
    cfg = registry.get_smoke_config(arch)
    if cfg.moe:
        cfg = replace(cfg, **{"moe.capacity_factor":
                              float(cfg.moe.n_experts / cfg.moe.top_k) * 1.1})
    model = Model(cfg)
    params = model.init(key)
    B, S, T = 2, 24, 3
    tokens = jax.random.randint(key, (B, S + T), 0, cfg.vocab_size)
    extras = _extras(cfg, key, B)
    logits_full, _ = model.apply(params, tokens, **extras)
    lp, cache = model.prefill(params, tokens[:, :S], cache_len=S + T,
                              **extras)
    np.testing.assert_allclose(np.asarray(lp),
                               np.asarray(logits_full[:, S - 1]),
                               atol=2e-4, rtol=2e-3)
    for t in range(T):
        ld, cache = model.decode(params, cache, tokens[:, S + t][:, None],
                                 S + t)
        np.testing.assert_allclose(np.asarray(ld),
                                   np.asarray(logits_full[:, S + t]),
                                   atol=2e-4, rtol=2e-3)


def test_sliding_window_cache_is_small():
    """gemma3 local layers must hold window-sized caches (the long_500k
    memory story)."""
    cfg = registry.get_smoke_config("gemma3-1b")
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.empty_cache(2, 4096))
    sizes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        p = "/".join(str(getattr(q, "key", q)) for q in path)
        sizes[p] = leaf.shape
    # unit kind 0 = local (window), kind 1 = global (full)
    local_k = [v for k, v in sizes.items() if k.startswith("units/0/a/k")]
    global_k = [v for k, v in sizes.items() if k.startswith("units/1/a/k")]
    assert local_k[0][2] == cfg.sliding_window
    assert global_k[0][2] == 4096


def test_param_counts_match_analytic():
    """init() parameter count ~= ModelConfig.param_count() (within ties,
    norms, and small vectors — 2%)."""
    for arch in ARCHS:
        cfg = registry.get_smoke_config(arch)
        model = Model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.05, \
            (arch, actual, analytic)
