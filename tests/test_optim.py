"""Optimizers: reference-step equivalence + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import OptimizerConfig
from repro.optim.api import init_optimizer


def _quadratic_data():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3]), "b": jnp.asarray(0.05)}
    return params, grads


def test_sgd_matches_pytorch_convention():
    """One nesterov step: d = g + wd*p; buf = d; step = d + m*buf."""
    cfg = OptimizerConfig(kind="sgd", momentum=0.9, nesterov=True,
                          weight_decay=0.01)
    init, update = init_optimizer(cfg)
    params, grads = _quadratic_data()
    state = init(params)
    new_params, state = update(grads, state, params, 0.1)
    d = np.asarray(grads["w"]) + 0.01 * np.asarray(params["w"])
    step = d + 0.9 * d
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               np.asarray(params["w"]) - 0.1 * step,
                               rtol=1e-6)


def test_sgd_momentum_accumulates():
    cfg = OptimizerConfig(kind="sgd", momentum=0.9, nesterov=False,
                          weight_decay=0.0)
    init, update = init_optimizer(cfg)
    params, grads = _quadratic_data()
    state = init(params)
    p1, state = update(grads, state, params, 0.1)
    p2, state = update(grads, state, p1, 0.1)
    # second step is larger in magnitude (momentum)
    step1 = np.abs(np.asarray(params["w"]) - np.asarray(p1["w"]))
    step2 = np.abs(np.asarray(p1["w"]) - np.asarray(p2["w"]))
    assert (step2 > step1).all()


def test_lars_scales_by_trust_ratio():
    cfg = OptimizerConfig(kind="lars", momentum=0.0, nesterov=False,
                          weight_decay=0.0, trust_coefficient=0.001)
    init, update = init_optimizer(cfg)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 2.0)}
    state = init(params)
    new_params, _ = update(grads, state, params, 1.0)
    trust = 0.001 * 4.0 / 8.0           # ||p||=4, ||g||=8
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               1.0 - trust * 2.0, rtol=1e-5)


def test_lars_skips_1d_params():
    cfg = OptimizerConfig(kind="lars", momentum=0.0, nesterov=False,
                          weight_decay=0.0)
    init, update = init_optimizer(cfg)
    params = {"b": jnp.ones((4,))}
    grads = {"b": jnp.full((4,), 2.0)}
    new_params, _ = update(grads, init(params), params, 0.1)
    np.testing.assert_allclose(np.asarray(new_params["b"]), 1.0 - 0.2,
                               rtol=1e-6)


def test_adamw_bias_correction_first_step():
    cfg = OptimizerConfig(kind="adamw", b1=0.9, b2=0.999, eps=1e-8,
                          weight_decay=0.0)
    init, update = init_optimizer(cfg)
    params, grads = _quadratic_data()
    new_params, _ = update(grads, init(params), params, 0.001)
    # first adam step ~= lr * sign(g)
    np.testing.assert_allclose(
        np.asarray(params["w"]) - np.asarray(new_params["w"]),
        0.001 * np.sign(np.asarray(grads["w"])), rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["sgd", "lars", "adamw"]),
       lr=st.floats(1e-5, 0.5), seed=st.integers(0, 50))
def test_property_optimizers_descend_quadratic(kind, lr, seed):
    """Any optimizer at any sane LR strictly decreases f(w)=||w||^2/2 from a
    random start within a few steps (gradient = w)."""
    cfg = OptimizerConfig(kind=kind, weight_decay=0.0, momentum=0.9)
    init, update = init_optimizer(cfg)
    w0 = jax.random.normal(jax.random.PRNGKey(seed), (8,)) + 3.0
    params = {"w": w0}
    state = init(params)
    f = lambda p: float(0.5 * jnp.sum(p["w"] ** 2))
    before = f(params)
    for step in range(5):
        grads = {"w": params["w"]}
        params, state = update(grads, state, params, lr)
    assert f(params) < before
