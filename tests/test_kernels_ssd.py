"""SSD (Mamba-2) kernel: sweeps vs the sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd_decode, ssd_scan


def _mk(B, S, H, P, G, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    return x, dt, A, Bm, Cm, D


SHAPES = [
    (1, 32, 2, 8, 1, 4),
    (2, 96, 4, 16, 2, 8),      # grouped B/C
    (2, 83, 4, 16, 1, 8),      # ragged (chunk padding path)
    (1, 64, 8, 32, 4, 16),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_matches_oracle(shape, impl):
    x, dt, A, Bm, Cm, D = _mk(*shape)
    y0, s0 = ssd_scan(x, dt, A, Bm, Cm, D, impl="naive")
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, D, impl=impl, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64, 128])
def test_chunk_size_invariance(chunk):
    x, dt, A, Bm, Cm, D = _mk(2, 64, 2, 8, 1, 4)
    y0, s0 = ssd_scan(x, dt, A, Bm, Cm, D, impl="naive")
    y, s = ssd_scan(x, dt, A, Bm, Cm, D, impl="reference", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0), atol=1e-4,
                               rtol=1e-4)


def test_init_state_chaining():
    """Running two halves with state carry == one full scan (the decode/
    chunked-prefill contract)."""
    x, dt, A, Bm, Cm, D = _mk(2, 64, 4, 16, 2, 8)
    y_full, s_full = ssd_scan(x, dt, A, Bm, Cm, D, impl="naive")
    yA, sA = ssd_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], D,
                      impl="reference", chunk=16)
    yB, sB = ssd_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], D,
                      impl="reference", chunk=16, init_state=sA)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([yA, yB], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sB), np.asarray(s_full), atol=1e-4,
                               rtol=1e-4)


def test_decode_step_matches_scan():
    x, dt, A, Bm, Cm, D = _mk(2, 17, 4, 8, 2, 4)
    y_full, s_full = ssd_scan(x, dt, A, Bm, Cm, D, impl="naive")
    state = jnp.zeros((2, 4, 8, 4))
    ys = []
    for t in range(17):
        y, state = ssd_decode(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D,
                              state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


def test_gradients():
    x, dt, A, Bm, Cm, D = _mk(1, 48, 2, 8, 1, 4)

    def loss(impl):
        return lambda x, dt: (
            ssd_scan(x, dt, A, Bm, Cm, D, impl=impl, chunk=16)[0] ** 2).mean()

    g0 = jax.grad(loss("naive"), argnums=(0, 1))(x, dt)
    for impl in ("reference", "pallas"):
        g = jax.grad(loss(impl), argnums=(0, 1))(x, dt)
        for a, b in zip(g0, g):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=1e-4, rtol=1e-3)
            assert np.isfinite(np.asarray(b)).all()


def test_pallas_backward_kernel_all_operands():
    """The true Pallas intra-chunk backward (dx/ddt/dA/dB/dC through the
    decay-matrix chain rule) vs oracle autodiff, including grouped B/C,
    ragged padding, and final-state cotangents."""
    x, dt, A, Bm, Cm, D = _mk(2, 83, 4, 16, 2, 8)   # ragged, grouped

    def loss(impl):
        def f(x, dt, Bm, Cm, D):
            y, s = ssd_scan(x, dt, A, Bm, Cm, D, impl=impl, chunk=32)
            return (y ** 2).mean() + (s ** 2).mean()
        return f

    g0 = jax.grad(loss("naive"), argnums=(0, 1, 2, 3, 4))(x, dt, Bm, Cm, D)
    g1 = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3, 4))(x, dt, Bm, Cm, D)
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-4,
                                   rtol=1e-3)


def test_pallas_intra_backward_matches_vjp():
    from repro.kernels.ssd.kernel import ssd_chunk_pallas_bwd
    from repro.kernels.ssd.ops import _intra_chunk_jnp
    key = jax.random.PRNGKey(3)
    B, S, H, P, G, N, chunk = 1, 64, 2, 8, 1, 4, 32
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    dy = jax.random.normal(ks[5], (B, S, H, P))
    dstates = jax.random.normal(ks[6], (B, S // chunk, H, P, N))
    dcum = jax.random.normal(ks[7], (B, S, H))
    _, vjp = jax.vjp(lambda *a: _intra_chunk_jnp(*a, chunk), x, dt, A, Bm,
                     Cm)
    want = vjp((dy, dstates, dcum))
    got = ssd_chunk_pallas_bwd(x, dt, A, Bm, Cm, dy, dstates, dcum,
                               chunk=chunk)
    for a, b in zip(want, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4,
                                   rtol=1e-4)
