"""Triton-lowered GPU kernel variants + tuning cache.

The forced-``triton`` impl runs the GPU Pallas programs under the
interpreter on this CPU host — same equivalence bar as the Mosaic kernel
tests (tolerances copied from test_kernels_flash_attention.py /
test_kernels_ssd.py; swa_avg stays BITWISE). Tuning-cache resolution is
unit-tested against a temp cache file: hit -> design applied, miss ->
deterministic default, malformed entry -> clear error naming the key.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.averaging import StreamingAverage
from repro.kernels import dispatch, tuning
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.swa_avg.ops import running_average
from repro.kernels.tuning import DEFAULT_DESIGN, DesignPoint
from repro.models.model import Model


# ---------------------------------------------------------------------------
# flash attention (forced triton, interpret mode)
# ---------------------------------------------------------------------------


def _mk_attn(B, Sq, Skv, H, KVH, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Skv, KVH, D))
    v = jax.random.normal(ks[2], (B, Skv, KVH, D))
    return q, k, v


ATTN_SHAPES = [
    (1, 16, 16, 4, 4, 16),      # MHA tiny
    (2, 67, 67, 8, 2, 32),      # GQA, ragged seq
    (2, 128, 128, 4, 1, 64),    # kv=1 (gemma-style)
    (1, 33, 129, 4, 2, 24),     # cross-length, odd dims
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_triton_matches_reference(shape, causal, window):
    B, Sq, Skv, H, KVH, D = shape
    q, k, v = _mk_attn(*shape)
    want = flash_attention(q, k, v, causal=causal, window=window,
                           impl="reference", chunk=32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          impl="triton", chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_triton_decode_offset():
    q, k, v = _mk_attn(2, 1, 64, 8, 4, 32)
    want = flash_attention(q, k, v, causal=True, q_offset=63,
                           impl="reference", chunk=16)
    got = flash_attention(q, k, v, causal=True, q_offset=63, impl="triton",
                          chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_triton_gradients(causal, window):
    q, k, v = _mk_attn(1, 32, 32, 4, 2, 16)

    def loss(impl):
        def f(q, k, v):
            o = flash_attention(q, k, v, causal=causal, window=window,
                                impl=impl, chunk=16)
            return jnp.sum(o * o)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    for got, want in zip(loss("triton"), loss("reference")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-4, rtol=5e-4)


def test_flash_triton_design_pin():
    """A pinned design point must produce the same numbers (it only
    re-tiles the same math)."""
    q, k, v = _mk_attn(1, 48, 48, 4, 2, 16)
    base = flash_attention(q, k, v, impl="triton")
    pinned = flash_attention(q, k, v, impl="triton",
                             design=DesignPoint(32, 16, 8, 3))
    np.testing.assert_allclose(np.asarray(pinned), np.asarray(base),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# ssd (forced triton, interpret mode)
# ---------------------------------------------------------------------------


def _mk_ssd(B, S, H, P, G, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    return x, dt, A, Bm, Cm, D


SSD_SHAPES = [
    (1, 32, 2, 8, 1, 4),
    (2, 96, 4, 16, 2, 8),      # grouped B/C
    (2, 83, 4, 16, 1, 8),      # ragged (chunk padding path)
    (1, 64, 8, 32, 4, 16),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_triton_matches_reference(shape):
    x, dt, A, Bm, Cm, D = _mk_ssd(*shape)
    y0, s0 = ssd_scan(x, dt, A, Bm, Cm, D, impl="reference", chunk=32)
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, D, impl="triton", chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), atol=1e-4,
                               rtol=1e-4)


def test_ssd_triton_gradients():
    x, dt, A, Bm, Cm, D = _mk_ssd(2, 64, 4, 16, 2, 8)

    def grads(impl):
        def f(x, dt, A, Bm, Cm, D):
            y, s = ssd_scan(x, dt, A, Bm, Cm, D, impl=impl, chunk=16)
            return jnp.sum(y * y) + jnp.sum(s * s)
        return jax.grad(f, argnums=(0, 1, 2, 3, 4, 5))(x, dt, A, Bm, Cm, D)

    for got, want in zip(grads("triton"), grads("reference")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-3)


def test_ssd_triton_init_state_chaining():
    x, dt, A, Bm, Cm, D = _mk_ssd(1, 64, 2, 8, 1, 4)
    y_full, s_full = ssd_scan(x, dt, A, Bm, Cm, D, impl="triton", chunk=16)
    yA, sA = ssd_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], D,
                      impl="triton", chunk=16)
    yB, sB = ssd_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], D,
                      init_state=sA, impl="triton", chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([yA, yB], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sB), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# swa_avg (forced triton, interpret mode — BITWISE)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(17,), (1000, 37), (3, 5, 7), (8192,)])
def test_swa_triton_bitwise_vs_reference(shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    avg = jax.random.normal(k1, shape)
    w = jax.random.normal(k2, shape)
    for n in (0.0, 1.0, 7.0):
        ref = running_average(avg, w, n, impl="reference")
        tri = running_average(avg, w, n, impl="triton")
        np.testing.assert_array_equal(np.asarray(tri), np.asarray(ref))


def test_swa_triton_bitwise_on_real_bundle():
    """Same bar as the Mosaic kernel: bitwise equality on every leaf shape
    of a real model bundle, via StreamingAverage(impl="triton")."""
    cfg = registry.get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    p1 = model.init(jax.random.PRNGKey(0))
    p2 = model.init(jax.random.PRNGKey(1))

    ref, tri = StreamingAverage(impl="reference"), StreamingAverage(
        impl="triton")
    for p in (p1, p2):
        ref.add(p)
        tri.add(p)
    flat_r = jax.tree_util.tree_flatten_with_path(ref.value())[0]
    flat_t = jax.tree_util.tree_flatten(tri.value())[0]
    assert len(flat_r) == len(flat_t) > 5
    for (path, leaf_r), leaf_t in zip(flat_r, flat_t):
        np.testing.assert_array_equal(
            np.asarray(leaf_r), np.asarray(leaf_t),
            err_msg=f"leaf {jax.tree_util.keystr(path)} "
                    f"shape {leaf_r.shape}")


# ---------------------------------------------------------------------------
# tuning cache resolution
# ---------------------------------------------------------------------------


@pytest.fixture
def temp_cache(tmp_path, monkeypatch):
    """Point the tuning module at a writable temp cache file."""
    path = tmp_path / "tuning_cache.json"
    monkeypatch.setattr(tuning, "CACHE_PATH", str(path))
    tuning.clear_cache()
    yield str(path)
    tuning.clear_cache()


def _write(path, entries):
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f)
    tuning.clear_cache()


def test_cache_hit_applies_design(temp_cache):
    _write(temp_cache, {"gpu/flash_attention/skv128_d32": {
        "block_q": 64, "block_k": 32, "num_warps": 8, "num_stages": 3}})
    d = dispatch.resolve("auto", backend="gpu", kernel="flash_attention",
                         shape=(128, 32))
    assert d.cache_hit
    assert d.design == DesignPoint(64, 32, 8, 3)
    # non-pow2 shapes bucket up: skv 100 -> 128, d 25 -> 32
    d = dispatch.resolve("auto", backend="gpu", kernel="flash_attention",
                         shape=(100, 25))
    assert d.cache_hit and d.design == DesignPoint(64, 32, 8, 3)


def test_cache_miss_falls_back_to_default(temp_cache):
    d = dispatch.resolve("auto", backend="gpu", kernel="flash_attention",
                         shape=(4096, 64))
    assert not d.cache_hit
    assert d.design == DEFAULT_DESIGN["flash_attention"]
    for kernel, shape in (("ssd", (2048, 64)), ("swa_avg", (12345,))):
        d = dispatch.resolve("auto", backend="gpu", kernel=kernel,
                             shape=shape)
        assert not d.cache_hit and d.design == DEFAULT_DESIGN[kernel]


def test_malformed_cache_entry_is_a_clear_error(temp_cache):
    _write(temp_cache, {"gpu/ssd/s64_p16": {
        "block_q": 0, "block_k": 0, "num_warps": 5, "num_stages": 2}})
    with pytest.raises(ValueError, match="gpu/ssd/s64_p16"):
        dispatch.resolve("auto", backend="gpu", kernel="ssd",
                         shape=(64, 16))
    _write(temp_cache, {"gpu/ssd/s64_p16": {"block_q": 0}})
    with pytest.raises(ValueError, match="missing field"):
        dispatch.resolve("auto", backend="gpu", kernel="ssd",
                         shape=(64, 16))


def test_explicit_design_pin_bypasses_cache(temp_cache):
    _write(temp_cache, {"gpu/ssd/s64_p16": {
        "block_q": 0, "block_k": 0, "num_warps": 8, "num_stages": 3}})
    d = dispatch.resolve("auto", backend="gpu", kernel="ssd",
                         shape=(64, 16), design=(0, 0, 2, 1))
    assert not d.cache_hit
    assert d.design == DesignPoint(0, 0, 2, 1)


def test_checked_in_cache_is_valid():
    data = tuning.load_cache()
    assert tuning.validate_cache(data) == []
    assert data.get("entries"), "checked-in tuning cache has no entries"


def test_config_validates_impls_and_design_pins():
    with pytest.raises(ValueError, match="KERNEL_IMPLS|expected one of"):
        registry.get_smoke_config("internlm2-1.8b")  # warm the registry
        import dataclasses
        dataclasses.replace(registry.get_smoke_config("internlm2-1.8b"),
                            attention_impl="palas")
    with pytest.raises(ValueError, match="4-tuple"):
        import dataclasses
        dataclasses.replace(registry.get_smoke_config("internlm2-1.8b"),
                            ssd_design=(1, 2))
    with pytest.raises(ValueError, match="StreamingAverage.impl"):
        StreamingAverage(impl="cuda")


def test_model_config_design_pin_reaches_kernel():
    """attention_design on the config flows through the attention layer to
    the kernel (numbers unchanged — a design point only re-tiles)."""
    import dataclasses
    cfg = registry.get_smoke_config("internlm2-1.8b")
    pinned = dataclasses.replace(cfg, attention_impl="triton",
                                 attention_design=(32, 32, 8, 3))
    base = dataclasses.replace(cfg, attention_impl="reference")
    tokens = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % cfg.vocab_size
    model_p, model_b = Model(pinned), Model(base)
    params = model_b.init(jax.random.PRNGKey(0))
    want, _ = model_b.apply(params, tokens)
    got, _ = model_p.apply(params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)
