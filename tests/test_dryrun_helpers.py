"""Dry-run helpers that don't need 512 devices: input specs, FLOP
accounting, shape applicability."""
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, registry, shape_applicable
from repro.launch.dryrun import input_specs, model_flops


def test_input_specs_train():
    cfg = registry.get_config("internlm2-1.8b")
    specs = input_specs(cfg, SHAPES["train_4k"])
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].shape == (256, 4096)
    assert specs["tokens"].dtype == jnp.int32


def test_input_specs_decode_is_one_token():
    cfg = registry.get_config("qwen2.5-14b")
    specs = input_specs(cfg, SHAPES["decode_32k"])
    assert specs["tokens"].shape == (128, 1)
    assert "labels" not in specs


def test_input_specs_modality_stubs():
    vlm = registry.get_config("qwen2-vl-72b")
    s = input_specs(vlm, SHAPES["prefill_32k"])
    assert s["vision_embeds"].shape == (32, vlm.n_vision_tokens, vlm.d_model)
    audio = registry.get_config("whisper-base")
    s = input_specs(audio, SHAPES["train_4k"])
    assert s["frames"].shape == (256, audio.encoder_seq, audio.d_model)


def test_model_flops_train_vs_decode():
    cfg = registry.get_config("internlm2-1.8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    # 6·N·D for training
    assert train == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)
    dec = model_flops(cfg, SHAPES["decode_32k"])
    # 2·N per generated token x batch
    assert dec == pytest.approx(2 * cfg.active_param_count() * 128, rel=1e-6)


def test_moe_uses_active_params():
    cfg = registry.get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
    f = model_flops(cfg, SHAPES["train_4k"])
    assert f == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)


def test_long_context_skips():
    skips, runs = [], []
    for arch in registry.ASSIGNED_ARCHS:
        cfg = registry.get_config(arch)
        (runs if shape_applicable(arch, cfg.family, SHAPES["long_500k"])
         else skips).append(arch)
    assert sorted(runs) == ["gemma3-1b", "mamba2-2.7b", "zamba2-7b"]
    assert len(skips) == 7
    # every other shape applies to every arch
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in registry.ASSIGNED_ARCHS:
            cfg = registry.get_config(arch)
            assert shape_applicable(arch, cfg.family, SHAPES[shape])
