"""DistConfig (repro.dist.config): the unified distribution surface —
mesh spec parsing, JSON round-trip, CLI flag resolution, validation, and
the deprecated ``mesh=`` shim."""
import argparse
import json

import jax
import pytest

from repro.dist.config import (DistConfig, add_dist_args, parse_mesh,
                               resolve_dist)


# ---------------------------------------------------------------------------
# parse_mesh
# ---------------------------------------------------------------------------


def test_parse_mesh_named():
    assert parse_mesh("worker:2,data:2,model:2") == (
        (2, 2, 2), ("worker", "data", "model"))
    assert parse_mesh("worker:8,data:2") == ((8, 2), ("worker", "data"))


def test_parse_mesh_bare_rank_defaults():
    assert parse_mesh("4") == ((4,), ("data",))
    assert parse_mesh("4x2") == ((4, 2), ("data", "model"))
    assert parse_mesh("2x2x2") == ((2, 2, 2), ("worker", "data", "model"))
    assert parse_mesh("2x2x2x2") == (
        (2, 2, 2, 2), ("pod", "worker", "data", "model"))


def test_parse_mesh_empty_and_errors():
    assert parse_mesh("") == ((), ())
    with pytest.raises(ValueError, match="named form"):
        parse_mesh("2x2x2x2x2")
    with pytest.raises(ValueError, match="name:size"):
        parse_mesh("worker:,data:2")


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_validation_rejects_bad_configs():
    with pytest.raises(ValueError, match="equal rank"):
        DistConfig(mesh_shape=(2, 2), mesh_axes=("worker",))
    with pytest.raises(ValueError, match="phase2_engine"):
        DistConfig(phase2_engine="pmap")
    with pytest.raises(ValueError, match="n_workers"):
        DistConfig(n_workers=0)
    with pytest.raises(ValueError, match="backoff"):
        DistConfig(elastic_backoff=0.5)
    with pytest.raises(ValueError, match="elastic_min_workers"):
        DistConfig(n_workers=2, elastic_min_workers=3)
    with pytest.raises(ValueError, match="coordinator"):
        DistConfig(num_processes=2)
    with pytest.raises(ValueError, match="process_id"):
        DistConfig(num_processes=2, process_id=2,
                   coordinator="localhost:9999")


def test_worker_axis_must_be_outermost():
    cfg = DistConfig(mesh_shape=(2, 4), mesh_axes=("data", "worker"))
    with pytest.raises(ValueError, match="outermost"):
        cfg.make_mesh()


# ---------------------------------------------------------------------------
# derived properties / engine resolution
# ---------------------------------------------------------------------------


def test_resolved_engine_auto():
    assert DistConfig().resolved_engine() == "vmap"
    worker = DistConfig(mesh_shape=(4, 2), mesh_axes=("worker", "data"),
                        n_workers=4)
    assert worker.resolved_engine() == "sharded"
    no_worker = DistConfig(mesh_shape=(4, 2), mesh_axes=("data", "model"))
    assert no_worker.resolved_engine() == "vmap"
    forced = DistConfig(phase2_engine="vmap", mesh_shape=(4, 2),
                        mesh_axes=("worker", "data"), n_workers=4)
    assert forced.resolved_engine() == "vmap"


def test_resolved_engine_prefers_runtime_mesh():
    mesh = jax.make_mesh((4, 2), ("worker", "data"))
    assert DistConfig().resolved_engine(mesh) == "sharded"


def test_data_shard():
    assert DistConfig().data_shard is None
    d = DistConfig(coordinator="localhost:9999", num_processes=4,
                   process_id=2)
    assert d.data_shard == (2, 4)


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------


def test_json_roundtrip(tmp_path):
    cfg = DistConfig(mesh_shape=(4, 2), mesh_axes=("worker", "data"),
                     n_workers=4, elastic_deadline_s=30.0,
                     elastic_min_workers=2, donate_state=False)
    assert DistConfig.from_json(cfg.to_json()) == cfg
    path = str(tmp_path / "dist.json")
    cfg.to_json(path)
    assert DistConfig.from_json(path) == cfg


def test_from_json_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown DistConfig keys"):
        DistConfig.from_json(json.dumps({"n_workres": 4}))


# ---------------------------------------------------------------------------
# CLI flag surface
# ---------------------------------------------------------------------------


def _parse(argv):
    ap = argparse.ArgumentParser()
    add_dist_args(ap)
    return ap.parse_args(argv)


def test_from_args_flags():
    cfg = DistConfig.from_args(_parse(
        ["--mesh", "worker:4,data:2", "--workers", "4",
         "--elastic-deadline", "30", "--elastic-min-workers", "2"]))
    assert cfg.mesh_shape == (4, 2)
    assert cfg.mesh_axes == ("worker", "data")
    assert cfg.n_workers == 4
    assert cfg.elastic_deadline_s == 30.0
    assert cfg.elastic_min_workers == 2


def test_from_args_defaults():
    cfg = DistConfig.from_args(_parse([]), n_workers_default=4)
    assert cfg == DistConfig(n_workers=4)


def test_from_args_file_plus_override(tmp_path):
    """Explicit flags override the --dist-config file; flags left at their
    parser default defer to it."""
    path = str(tmp_path / "dist.json")
    DistConfig(mesh_shape=(4, 2), mesh_axes=("worker", "data"), n_workers=4,
               elastic_deadline_s=10.0).to_json(path)
    cfg = DistConfig.from_args(_parse(
        ["--dist-config", path, "--elastic-deadline", "99"]))
    assert cfg.mesh_shape == (4, 2)           # from the file
    assert cfg.n_workers == 4                 # from the file (flag unset)
    assert cfg.elastic_deadline_s == 99.0     # flag wins


# ---------------------------------------------------------------------------
# resolve_dist: the deprecated mesh= shim
# ---------------------------------------------------------------------------


def test_resolve_dist_mesh_shim_warns_and_works():
    mesh = jax.make_mesh((4, 2), ("worker", "data"))
    with pytest.warns(DeprecationWarning, match="mesh=.*deprecated"):
        dist, out_mesh = resolve_dist(None, mesh, caller="SWAP")
    assert out_mesh is mesh                   # passed mesh used as-is
    assert dist.mesh_shape == (4, 2)
    assert dist.mesh_axes == ("worker", "data")
    assert dist.n_workers == 4


def test_resolve_dist_rejects_both():
    mesh = jax.make_mesh((4, 2), ("worker", "data"))
    with pytest.raises(ValueError, match="not both"):
        resolve_dist(DistConfig(), mesh, caller="SWAP")


def test_resolve_dist_neither():
    dist, mesh = resolve_dist()
    assert dist == DistConfig() and mesh is None


def test_swap_mesh_kwarg_still_works():
    """The SWAP constructor's old mesh= spelling keeps working for one
    release behind the DeprecationWarning shim."""
    from repro.configs import registry
    from repro.configs.base import (OptimizerConfig, PhaseConfig,
                                    ScheduleConfig, SWAPConfig)
    from repro.core.adapters import LMAdapter
    from repro.core.swap import SWAP
    from repro.data.pipeline import Loader, make_markov_lm

    cfg = registry.get_smoke_config("internlm2-1.8b")
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=64, n_test=32,
                          seq_len=8)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    swap_cfg = SWAPConfig(
        n_workers=4,
        phase1=PhaseConfig(batch_size=16, max_steps=1,
                           schedule=ScheduleConfig(kind="const")),
        phase2=PhaseConfig(batch_size=16, max_steps=1,
                           schedule=ScheduleConfig(kind="const")))
    mesh = jax.make_mesh((4, 2), ("worker", "data"))
    with pytest.warns(DeprecationWarning):
        s = SWAP(adapter, swap_cfg, train, Loader(train, 32), mesh=mesh)
    assert s.mesh is mesh
    assert s.dist.n_workers == 4
    assert s.dist.resolved_engine(s.mesh) == "sharded"
