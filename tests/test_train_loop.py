"""Phase engine (repro.train.loop): the scan-based epoch runner must
reproduce the per-step Python loop exactly, stop at epoch boundaries, and —
vmapped with the in-trace batch gather on a worker mesh — lower with no
cross-worker collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig, OptimizerConfig, ScheduleConfig
from repro.core.adapters import CNNAdapter, LMAdapter
from repro.core.schedules import schedule_fn
from repro.core.swap import _stack_bundles
from repro.data.pipeline import Loader, make_gmm_images, make_markov_lm
from repro.dist.sharding import (assert_no_cross_worker_collectives,
                                 ensemble_shardings)
from repro.train.loop import (EpochRunner, init_train_state,
                              python_loop_reference, run_phase,
                              stack_train_state)


def tiny_lm() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=32, attention="gqa",
        dtype="float32", remat=False, scan_layers=False)


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_logs_match(ref_log, log, exact=True):
    """Per-step trajectories across the two engines. The EMA is always
    compared to f32-ulp tolerance because XLA contracts ``b*ema +
    (1-b)*acc`` into an FMA inside the compiled chunk (one rounding) while
    the eager reference rounds twice; with ``exact=False`` the step outputs
    get the same treatment (conv/BN fusion differs between the scanned and
    standalone compilations of the CNN step)."""
    assert [e["step"] for e in ref_log] == [e["step"] for e in log]
    for k in ("accuracy", "loss", "lr"):
        if exact:
            assert [e[k] for e in ref_log] == [e[k] for e in log], k
        else:
            np.testing.assert_allclose([e[k] for e in ref_log],
                                       [e[k] for e in log],
                                       rtol=1e-5, atol=1e-7, err_msg=k)
    np.testing.assert_allclose([e["ema"] for e in ref_log],
                               [e["ema"] for e in log], rtol=1e-5, atol=1e-9)


def _lm_pieces(n_train=128, batch=16, seq_len=16, seed=0):
    cfg = tiny_lm()
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(seed, vocab=cfg.vocab_size, n_train=n_train,
                          n_test=32, seq_len=seq_len)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, batch, seed=3)
    step_fn = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="warmup_linear", peak_lr=0.1, warmup_steps=3,
                       total_steps=12)))
    return adapter, loader, step_fn


def _fresh_state(adapter, key=1):
    bundle = adapter.init(jax.random.PRNGKey(key))
    return init_train_state(bundle, adapter.init_opt(bundle))


def test_scan_matches_python_loop_lm():
    """Same params AND same per-step metric/EMA trajectory, bitwise, on the
    Markov-LM task (12 steps across an epoch boundary: spe=8)."""
    adapter, loader, step_fn = _lm_pieces()
    n = 12
    assert loader.steps_per_epoch == 8  # crosses an epoch boundary

    ref_state, ref_log = python_loop_reference(
        step_fn, loader, _fresh_state(adapter), n_steps=n, ema_beta=0.9)

    runner = EpochRunner(step_fn, loader, 0.9)
    log = []
    res = run_phase(runner, _fresh_state(adapter), 0, max_steps=n, log=log)

    _assert_trees_equal(ref_state.bundle, res.state.bundle)
    _assert_trees_equal(ref_state.opt_state, res.state.opt_state)
    _assert_logs_match(ref_log, log)
    assert float(np.asarray(res.state.acc_ema)) == log[-1]["ema"]


def test_scan_matches_python_loop_cnn():
    """Same equivalence on the GMM-image task through the CNN+BN adapter —
    this also exercises the traced aug_seed path (augmentation consumes it)
    and the BN state flowing through the scan carry. Conv/BN ops compile
    with different fusion inside scan than standalone, so this task gets
    tight tolerances instead of the LM's bitwise equality."""
    cfg = registry.get_smoke_config("cifar-cnn")
    adapter = CNNAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_gmm_images(0, n_classes=10, image_size=16, n_train=128,
                           n_test=32, noise=2.0)
    train = {"images": data["train_images"], "labels": data["train_labels"]}
    loader = Loader(train, 16, seed=5)
    step_fn = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="const", peak_lr=0.1)))
    n = 10  # spe=8 -> crosses an epoch boundary

    ref_state, ref_log = python_loop_reference(
        step_fn, loader, _fresh_state(adapter), n_steps=n, ema_beta=0.9)

    runner = EpochRunner(step_fn, loader, 0.9)
    log = []
    res = run_phase(runner, _fresh_state(adapter), 0, max_steps=n, log=log)

    for a, b in zip(jax.tree_util.tree_leaves(ref_state.bundle),
                    jax.tree_util.tree_leaves(res.state.bundle)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-7)
    _assert_logs_match(ref_log, log, exact=False)


def test_early_exit_at_epoch_boundary():
    """EMA stopping is checked at chunk granularity: a threshold crossed
    during an epoch stops at that epoch's boundary, never mid-chunk; a
    threshold already met at entry (e.g. a restored state) runs nothing."""
    adapter, loader, step_fn = _lm_pieces()
    runner = EpochRunner(step_fn, loader, 0.9)
    res = run_phase(runner, _fresh_state(adapter), 0, max_steps=40,
                    stop_accuracy=1e-6)  # crossed within the first epoch
    assert res.steps == loader.steps_per_epoch
    assert int(np.asarray(res.state.step)) == loader.steps_per_epoch

    # entry check: resuming an already-converged state trains zero steps
    res2 = run_phase(runner, res.state, 0, max_steps=40, stop_accuracy=1e-6)
    assert res2.steps == 0
    assert int(np.asarray(res2.state.step)) == loader.steps_per_epoch


def test_mid_chunk_entry_realigns_to_epoch_boundaries():
    """Regression: a phase entered at a non-boundary step (a snapshot cut
    mid-epoch, e.g. by a max_steps cap) must truncate its FIRST chunk to
    the next epoch boundary. The old driver ran full-length chunks from
    the resume offset, so every subsequent 'epoch boundary' — where the
    EMA stopping check and the on_chunk hooks run — was shifted by the
    offset for the rest of the phase."""
    adapter, loader, step_fn = _lm_pieces()
    spe = loader.steps_per_epoch
    assert spe == 8

    # a state 3 steps into an epoch, as a mid-chunk snapshot would leave
    # it (rebuilt per consumer: both engines donate their input buffers)
    def entry():
        st, _ = python_loop_reference(step_fn, loader,
                                      _fresh_state(adapter), n_steps=3,
                                      ema_beta=0.9)
        return st

    boundaries = []
    runner = EpochRunner(step_fn, loader, 0.9)
    log = []
    res = run_phase(runner, entry(), 0, max_steps=10, log=log,
                    on_chunk=lambda st, done: boundaries.append(
                        int(np.asarray(st.step))))
    # chunks [5, 5]: the first is truncated to the boundary at step 8
    assert boundaries == [spe, 13]
    assert res.steps == 10

    # realignment only reschedules chunk cuts — the trajectory is still
    # bitwise the uninterrupted one
    full_state, full_log = python_loop_reference(
        step_fn, loader, entry(), n_steps=10, ema_beta=0.9)
    _assert_trees_equal(full_state.bundle, res.state.bundle)
    _assert_logs_match(full_log, log)


def test_worker_identity_changes_data_order():
    """The in-trace gather must honor the traced worker id: two workers
    stepping from identical state diverge (different permutations)."""
    adapter, loader, step_fn = _lm_pieces()
    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True)
    bundle = adapter.init(jax.random.PRNGKey(0))
    stacked = _stack_bundles(bundle, 2)
    state = stack_train_state(stacked, jax.vmap(adapter.init_opt)(stacked), 2)
    out, _ = runner.run_chunk(state, jnp.arange(2, dtype=jnp.int32), 4)
    diffs = jax.tree_util.tree_map(
        lambda a: float(jnp.abs(a[0] - a[1]).max()), out.bundle["params"])
    assert max(jax.tree_util.tree_leaves(diffs)) > 1e-6


# ---------------------------------------------------------------------------
# phase-2 no-synchronization property on the scanned + vmapped engine
# ---------------------------------------------------------------------------

W = 2
PER_WORKER = 4  # data=2 x model=2 inside each worker block


def test_phase2_scan_epoch_has_no_cross_worker_collectives():
    """The whole scanned epoch — in-trace permutation, batch gather, W
    vmapped train steps per iteration — must lower onto the worker mesh
    with every collective contained inside one worker block."""
    if len(jax.devices()) < W * PER_WORKER:
        pytest.skip(f"needs {W * PER_WORKER} devices "
                    f"(conftest forces 8 on CPU hosts)")
    mesh = jax.make_mesh((W, 2, 2), ("worker", "data", "model"))

    cfg = registry.get_smoke_config("internlm2-1.8b")
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=64, n_test=32,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, 8, seed=1)
    step_fn = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="const", peak_lr=0.05)))

    bundle = adapter.init(jax.random.PRNGKey(0))
    stacked = _stack_bundles(bundle, W)
    state = stack_train_state(stacked, jax.vmap(adapter.init_opt)(stacked), W)
    state = jax.device_put(state, ensemble_shardings(mesh, state))
    workers = jax.device_put(
        jnp.arange(W, dtype=jnp.int32),
        ensemble_shardings(mesh, jnp.arange(W, dtype=jnp.int32)))

    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True)
    fn = runner._chunk_fn(loader.steps_per_epoch)
    hlo = fn.lower(state, workers).compile().as_text()
    assert_no_cross_worker_collectives(hlo, n_workers=W,
                                       devices_per_worker=PER_WORKER)
