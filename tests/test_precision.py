"""Mixed precision + gradient accumulation (repro.train.precision).

The load-bearing equivalences: the pure-f32 policy reproduces the plain
step bitwise (so the engine's python-loop equivalence is untouched),
``grad_accum_steps=k`` matches the fused batch to FMA tolerance, dynamic
loss scaling skips non-finite steps without corrupting state, bf16 phase-1
still yields averaged-beats-workers, and a non-f32 TrainState — loss-scale
dynamics and skipped-step counters included — checkpoint/resumes bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, OptimizerConfig, PhaseConfig,
                                ScheduleConfig, SWAPConfig)
from repro.core.adapters import LMAdapter
from repro.core.schedules import schedule_fn
from repro.core.swap import SWAP
from repro.data.pipeline import Loader, make_markov_lm
from repro.optim.api import init_optimizer
from repro.train.loop import EpochRunner, init_train_state, run_phase
from repro.train.precision import (
    BF16, F16, F32, LossScaleState, PrecisionPolicy, default_scale_state,
    make_precision_train_step, resolve_policy, split_microbatches,
)


def tiny_lm() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=32, attention="gqa",
        dtype="float32", remat=False, scan_layers=False)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# policy resolution / presets
# ---------------------------------------------------------------------------


def test_presets_resolve_by_any_alias():
    assert resolve_policy("f32") is F32
    assert resolve_policy("") is F32
    assert resolve_policy("bf16") is BF16
    assert resolve_policy("BFLOAT16") is BF16
    assert resolve_policy("fp16") is F16
    assert F16.dynamic and F16.loss_scale > 1.0
    assert not BF16.dynamic and BF16.compute_dtype == "bfloat16"
    with pytest.raises(ValueError, match="unknown precision preset"):
        resolve_policy("int8")


def test_deprecated_grad_dtype_folds_into_policy():
    """Satellite: OptimizerConfig.grad_dtype still parses, but now lands on
    the policy (cast inside the precision step, not a loose post-grad cast)
    and warns."""
    opt_cfg = OptimizerConfig(kind="sgd", grad_dtype="bfloat16")
    with pytest.warns(DeprecationWarning, match="grad_dtype is deprecated"):
        policy = resolve_policy("float32", opt_cfg)
    assert policy.grad_dtype == "bfloat16"
    # a policy that already sets grad_dtype wins silently over the alias —
    # and the f32 default never warns
    assert resolve_policy("float32",
                          OptimizerConfig(kind="sgd")).grad_dtype == "float32"


def test_split_microbatches_shapes_and_errors():
    batch = {"tokens": jnp.arange(24).reshape(8, 3),
             "aug_seed": jnp.int32(7)}
    micro = split_microbatches(batch, 4)
    assert micro["tokens"].shape == (4, 2, 3)
    # scalar leaves broadcast (one aug seed per global batch)
    np.testing.assert_array_equal(np.asarray(micro["aug_seed"]), [7] * 4)
    # reassembling the microbatches recovers the original order
    np.testing.assert_array_equal(
        np.asarray(micro["tokens"].reshape(8, 3)),
        np.asarray(batch["tokens"]))
    with pytest.raises(ValueError, match="not divisible"):
        split_microbatches(batch, 3)


def test_update_scale_dynamics():
    pol = PrecisionPolicy(name="t", dynamic=True, loss_scale=16.0,
                          growth_interval=2)
    st = pol.init_scale_state()
    t, f = jnp.asarray(True), jnp.asarray(False)
    st = pol.update_scale(st, t)            # finite: count 0 -> 1
    assert (float(st.scale), int(st.growth_count), int(st.skipped)) \
        == (16.0, 1, 0)
    st = pol.update_scale(st, t)            # finite: interval hit -> grow
    assert (float(st.scale), int(st.growth_count)) == (32.0, 0)
    st = pol.update_scale(st, f)            # overflow: back off + count it
    assert (float(st.scale), int(st.growth_count), int(st.skipped)) \
        == (16.0, 0, 1)


# ---------------------------------------------------------------------------
# step equivalences
# ---------------------------------------------------------------------------


def _lm_pieces(batch=32, n_train=128, seed=0):
    cfg = tiny_lm()
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(seed, vocab=cfg.vocab_size, n_train=n_train,
                          n_test=32, seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, batch, seed=3)
    sched = schedule_fn(ScheduleConfig(kind="const", peak_lr=0.1))
    return adapter, loader, sched


def _run_steps(adapter, loader, step_fn, n=4, scale=None):
    bundle = adapter.init(jax.random.PRNGKey(1))
    opt = adapter.init_opt(bundle)
    scale = scale if scale is not None else default_scale_state()
    fn = jax.jit(step_fn)
    metrics = None
    for s in range(n):
        bundle, opt, scale, metrics = fn(bundle, opt, loader.batch(s), s,
                                         scale)
    return bundle, opt, scale, metrics


def test_f32_policy_step_is_bitwise_plain():
    """The default policy must trace the exact pre-precision step graph: no
    casts, no scaling, no selects — same params bitwise as a hand-rolled
    value_and_grad + optimizer update."""
    adapter, loader, sched = _lm_pieces()
    opt_cfg = adapter.opt_cfg
    _, opt_update = init_optimizer(opt_cfg)

    def plain_step(bundle, opt_state, batch, step, scale_state):
        from repro.train.steps import lm_loss_and_metrics

        def loss_fn(p):
            return lm_loss_and_metrics(adapter.model, p, batch)
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(bundle["params"])
        lr = sched(step)
        new_p, new_opt = opt_update(grads, opt_state, bundle["params"], lr)
        return ({"params": new_p, "state": {}}, new_opt, scale_state,
                dict(metrics, lr=lr))

    b_ref, o_ref, _, m_ref = _run_steps(adapter, loader, plain_step)
    b_new, o_new, sc, m_new = _run_steps(
        adapter, loader, adapter.make_train_step(sched))
    _assert_trees_equal(b_ref["params"], b_new["params"])
    _assert_trees_equal(o_ref, o_new)
    assert float(m_ref["loss"]) == float(m_new["loss"])
    assert float(sc.scale) == 1.0 and int(sc.skipped) == 0


@pytest.mark.parametrize("precision,k,rtol,atol", [
    ("float32", 4, 2e-5, 1e-6),
    ("bfloat16", 2, 2e-2, 1e-3),   # bf16 compute: ~3 decimal digits
])
def test_grad_accum_matches_fused_batch(precision, k, rtol, atol):
    """ISSUE acceptance: grad_accum_steps=k over microbatches of B/k must
    match the fused batch-B step to FMA tolerance — identical effective
    batch size, only the loop structure differs."""
    adapter, loader, sched = _lm_pieces()
    policy = resolve_policy(precision)
    fused = adapter.make_train_step(sched, policy=policy)
    accum = adapter.make_train_step(sched, policy=policy,
                                    grad_accum_steps=k)
    b_f, o_f, _, m_f = _run_steps(adapter, loader, fused, n=3)
    b_a, o_a, _, m_a = _run_steps(adapter, loader, accum, n=3)
    _assert_trees_close(b_f["params"], b_a["params"], rtol=rtol, atol=atol)
    np.testing.assert_allclose(float(m_f["loss"]), float(m_a["loss"]),
                               rtol=rtol)
    np.testing.assert_allclose(float(m_f["accuracy"]), float(m_a["accuracy"]),
                               rtol=rtol, atol=atol)


def test_grad_accum_rejects_bad_factor():
    adapter, _, sched = _lm_pieces()
    with pytest.raises(ValueError, match="grad_accum_steps"):
        adapter.make_train_step(sched, grad_accum_steps=0)


def test_dynamic_scaling_skips_nonfinite_steps():
    """f16-style skip semantics on a transparent scalar model: an overflow
    step leaves params/opt state untouched, backs the scale off, counts the
    skip; finite steps apply exactly g = d(loss)/dw despite the scaling."""
    policy = PrecisionPolicy(name="test16", loss_scale=8.0, dynamic=True,
                             growth_factor=2.0, backoff_factor=0.5,
                             growth_interval=2)
    opt_cfg = OptimizerConfig(kind="sgd", momentum=0.0, nesterov=False,
                              weight_decay=0.0)
    _, opt_update = init_optimizer(opt_cfg)

    def loss_with_aux(p, st, batch):
        loss = jnp.sum(p["w"] * batch["x"])
        return loss, ({"loss": loss, "accuracy": jnp.float32(1.0),
                       "aux": jnp.float32(0.0)}, st)

    step_fn = make_precision_train_step(
        loss_with_aux, opt_update, lambda s: jnp.float32(0.5),
        policy=policy)
    bundle = {"params": {"w": jnp.asarray([1.0, 2.0])}, "state": {}}
    opt = {"mu": {"w": jnp.zeros((2,))}}
    scale = policy.init_scale_state()

    x = jnp.asarray([3.0, -1.0])
    bundle, opt, scale, m = step_fn(bundle, opt, {"x": x}, 0, scale)
    # grads unscaled exactly (power-of-two scale): w -= lr * x
    np.testing.assert_allclose(np.asarray(bundle["params"]["w"]),
                               [1.0 - 0.5 * 3.0, 2.0 + 0.5 * 1.0])
    assert (float(m["skipped"]), float(m["loss_scale"])) == (0.0, 8.0)
    assert (float(scale.scale), int(scale.growth_count)) == (8.0, 1)

    w_before = np.asarray(bundle["params"]["w"]).copy()
    bundle, opt, scale, m = step_fn(
        bundle, opt, {"x": jnp.asarray([jnp.inf, 0.0])}, 1, scale)
    np.testing.assert_array_equal(np.asarray(bundle["params"]["w"]),
                                  w_before)          # step skipped
    assert float(m["skipped"]) == 1.0
    assert float(scale.scale) == 4.0                 # backed off
    assert int(scale.skipped) == 1
    # optimizer state also kept its pre-skip value (buf = d = x, m=0)
    np.testing.assert_array_equal(np.asarray(opt["mu"]["w"]), [3.0, -1.0])

    # the backoff reset the growth counter: two consecutive finite steps
    # must pass before the scale grows back
    bundle, opt, scale, m = step_fn(bundle, opt, {"x": x}, 2, scale)
    assert (float(scale.scale), int(scale.growth_count)) == (4.0, 1)
    bundle, opt, scale, m = step_fn(bundle, opt, {"x": x}, 3, scale)
    assert float(scale.scale) == 8.0                 # grew back
    assert int(scale.skipped) == 1


def test_engine_freezes_ema_on_skipped_steps():
    """The phase engine must not absorb a skipped step's accuracy into the
    stopping EMA (run through EpochRunner, not the bare step)."""
    policy = PrecisionPolicy(name="test16", loss_scale=4.0, dynamic=True)
    opt_cfg = OptimizerConfig(kind="sgd", momentum=0.0, nesterov=False,
                              weight_decay=0.0)
    _, opt_update = init_optimizer(opt_cfg)

    def loss_with_aux(p, st, batch):
        # batches with x[0] == 5 overflow (inf * w in the backward)
        bad = batch["x"][0] == 5.0
        loss = jnp.sum(p["w"] * jnp.where(bad, jnp.inf, batch["x"]))
        return loss, ({"loss": loss, "accuracy": jnp.float32(1.0),
                       "aux": jnp.float32(0.0)}, st)

    step_fn = make_precision_train_step(
        loss_with_aux, opt_update, lambda s: jnp.float32(0.1),
        policy=policy)
    # 4 single-sample "batches": steps 1 and 3 overflow
    loader = Loader({"x": np.asarray([1.0, 5.0, 2.0, 5.0])[:, None]}, 1,
                    seed=0)
    runner = EpochRunner(step_fn, loader, ema_beta=0.5)
    state = init_train_state(
        {"params": {"w": jnp.ones((1,))}, "state": {}},
        {"mu": {"w": jnp.zeros((1,))}}, scale=policy.init_scale_state())
    res = run_phase(runner, state, 0, max_steps=4)
    # two skipped steps recorded in the carried scale state
    assert int(np.asarray(res.state.scale.skipped)) == 2
    # EMA only absorbed the two finite steps: 0 ->(finite) 0.5 ->(skip) 0.5
    # ->(finite) 0.75 ->(skip) 0.75
    np.testing.assert_allclose(float(np.asarray(res.state.acc_ema)), 0.75)


# ---------------------------------------------------------------------------
# end-to-end: bf16 phase 1 + f32 phase 2, and non-f32 checkpoint/resume
# ---------------------------------------------------------------------------


def _task(n_train=256):
    cfg = tiny_lm()
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=n_train,
                          n_test=128, seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    test_loader = Loader({"tokens": data["test_tokens"],
                          "labels": data["test_labels"]}, 128)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    return adapter, train, test_loader


def _swap_cfg(precision="float32", grad_accum=1, ckpt_dir="",
              ckpt_every=0) -> SWAPConfig:
    return SWAPConfig(
        n_workers=4,
        phase1=PhaseConfig(batch_size=32, max_steps=24,
                           precision=precision, grad_accum_steps=grad_accum,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.2)),
        phase2=PhaseConfig(batch_size=32, max_steps=12,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.05)),
        bn_recompute_batch_size=64, bn_recompute_batches=2, seed=0,
        checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every)


def test_bf16_phase1_swap_averaged_beats_workers():
    """ISSUE acceptance: bf16 phase-1 + f32 phase-2 still shows the paper's
    claim structure on the smoke task — training learns, and the averaged
    model is at least the worker mean (same margin as the f32 integration
    test)."""
    adapter, train, test_loader = _task()
    res = SWAP(adapter, _swap_cfg(precision="bfloat16", grad_accum=2),
               train, test_loader).run(jax.random.PRNGKey(0))
    assert res["phase1_skipped_steps"] == 0          # bf16 needs no scaling
    assert res["phase1_train_acc"] > 0.2             # it actually learned
    assert np.isfinite(res["after_avg_test_acc"])
    assert res["after_avg_test_acc"] >= res["before_avg_test_acc"] - 0.01


def test_resume_non_f32_state_is_bitwise(tmp_path):
    """Satellite: mid-phase-1 resume of an f16(dynamic scaling)+accumulation
    run is bitwise-exact — params AND loss-scale state (current scale,
    growth counter, cumulative skipped steps) recovered from the snapshot."""
    adapter, train, test_loader = _task(n_train=128)

    def cfg_for(d):
        # batch 32 over 128 samples -> spe 4; phase-1 chunks [4, 4] with a
        # snapshot at step 4 = the interruption point
        return SWAPConfig(
            n_workers=2,
            phase1=PhaseConfig(batch_size=32, max_steps=8,
                               precision="float16", grad_accum_steps=2,
                               schedule=ScheduleConfig(kind="const",
                                                       peak_lr=0.1)),
            phase2=PhaseConfig(batch_size=32, max_steps=4,
                               schedule=ScheduleConfig(kind="const",
                                                       peak_lr=0.05)),
            bn_recompute_batch_size=64, bn_recompute_batches=2, seed=0,
            checkpoint_dir=str(d), checkpoint_every=4)

    dir_a = tmp_path / "a"
    res_a = SWAP(adapter, cfg_for(dir_a), train, test_loader).run(
        jax.random.PRNGKey(0))

    # simulate the kill: keep only the step-4 mid-phase-1 snapshot
    dir_b = tmp_path / "b"
    dir_b.mkdir()
    import shutil
    for name in ("phase1-step00000004.msgpack",
                 "phase1-step00000004.msgpack.json"):
        shutil.copy(dir_a / name, dir_b / name)
    res_b = SWAP(adapter, cfg_for(dir_b), train, test_loader).run(
        jax.random.PRNGKey(0), resume=True)

    _assert_trees_equal(res_a["final_bundle"]["params"],
                        res_b["final_bundle"]["params"])
    _assert_trees_equal(res_a["stacked_params"], res_b["stacked_params"])
    # loss-scale dynamics recovered exactly (skips + current scale)
    assert res_b["phase1_skipped_steps"] == res_a["phase1_skipped_steps"]
    assert res_b["phase1_loss_scale"] == res_a["phase1_loss_scale"]
    assert res_b["after_avg_test_acc"] == res_a["after_avg_test_acc"]


def test_pre_precision_snapshot_still_resumes(tmp_path):
    """Snapshots written before TrainState grew its scale field must stay
    loadable: the missing scale leaves backfill from the template (the
    policy's initial state), everything else restores byte-exact."""
    from repro.checkpoint.io import save_pytree
    from repro.checkpoint.state import _state_tree, load_train_state
    bundle = {"params": {"w": jnp.arange(4.0)}, "state": {}}
    opt = {"mu": {"w": jnp.zeros(4)}}
    state = init_train_state(bundle, opt, step=12, acc_ema=0.5)
    legacy = {k: v for k, v in _state_tree(state).items() if k != "scale"}
    path = str(tmp_path / "old.msgpack")
    save_pytree(path, legacy)

    out = load_train_state(path, init_train_state(bundle, opt))
    np.testing.assert_array_equal(np.asarray(out.bundle["params"]["w"]),
                                  np.arange(4.0))
    assert int(out.step) == 12 and float(out.acc_ema) == 0.5
    assert float(out.scale.scale) == 1.0 and int(out.scale.skipped) == 0
    # other missing leaves are still a hard error
    with pytest.raises(KeyError, match="missing leaf"):
        load_pytree_missing = {k: v for k, v in legacy.items()
                               if k != "acc_ema"}
        save_pytree(path, load_pytree_missing)
        load_train_state(path, init_train_state(bundle, opt))


def test_cnn_grad_accum_trains(tmp_path):
    """Accumulation through the CNN adapter: BN batch statistics are
    per-MICROBATCH under accumulation (k sequential running-stat updates),
    so fused-vs-accum equivalence holds only for stateless models (the LM
    tests above) — here we pin that the BN path still trains and carries
    dtype-stable state through the scan."""
    from repro.configs import registry
    from repro.core.adapters import CNNAdapter
    from repro.data.pipeline import make_gmm_images
    cfg = registry.get_smoke_config("cifar-cnn")
    adapter = CNNAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_gmm_images(0, n_classes=4, image_size=16, n_train=64,
                           n_test=16, noise=2.0)
    loader = Loader({"images": data["train_images"],
                     "labels": data["train_labels"]}, 32, seed=0)
    sched = schedule_fn(ScheduleConfig(kind="const", peak_lr=0.1))
    step_fn = adapter.make_train_step(sched, policy=resolve_policy("bf16"),
                                      grad_accum_steps=4)
    b0 = adapter.init(jax.random.PRNGKey(0))
    bundle, opt, scale, m = jax.jit(step_fn)(
        b0, adapter.init_opt(b0), loader.batch(0), 0,
        default_scale_state())
    assert np.isfinite(float(m["loss"]))
    # params moved, BN state stayed in its master dtype
    moved = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(b0["params"]),
        jax.tree_util.tree_leaves(bundle["params"])))
    assert moved > 0
    for leaf in jax.tree_util.tree_leaves(bundle["state"]):
        assert leaf.dtype == jnp.float32


def test_scale_state_checkpoints_byte_exact(tmp_path):
    """A nontrivial LossScaleState round-trips through the checkpoint layer
    (uniform TrainState structure regardless of policy)."""
    from repro.checkpoint.state import load_train_state, save_train_state
    bundle = {"params": {"w": jnp.ones((2, 2), jnp.bfloat16)}, "state": {}}
    opt = {"mu": {"w": jnp.zeros((2, 2))}}
    scale = LossScaleState(scale=jnp.float32(1024.0),
                           growth_count=jnp.int32(37),
                           skipped=jnp.int32(5))
    state = init_train_state(bundle, opt, step=9, scale=scale)
    path = str(tmp_path / "st.msgpack")
    save_train_state(path, state, meta={"tag": "phase1", "step": 9})
    out = load_train_state(path, init_train_state(bundle, opt))
    _assert_trees_equal(state, out)
    assert float(out.scale.scale) == 1024.0
    assert int(out.scale.skipped) == 5
