"""MoE: gather dispatch vs dense oracle, capacity behavior, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import replace
from repro.models.moe import capacity, init_moe, moe_forward, moe_forward_dense


def _cfg(cf=None):
    cfg = registry.get_smoke_config("qwen3-moe-235b-a22b")
    if cf is not None:
        cfg = replace(cfg, **{"moe.capacity_factor": cf})
    return cfg


def test_matches_dense_oracle_no_drop():
    cfg = _cfg(cf=float(4 / 2) * 1.5)   # capacity >= worst case
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y1, a1 = moe_forward(params, x, cfg)
    y2, a2 = moe_forward_dense(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_capacity_drops_are_bounded():
    """With a tight capacity factor outputs differ from dense (drops) but
    stay finite, and most tokens keep their experts."""
    cfg = _cfg(cf=1.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = moe_forward(params, x, cfg)
    assert bool(jnp.isfinite(y).all())
    yd, _ = moe_forward_dense(params, x, cfg)
    # dropped fraction: rows where outputs differ materially
    diff = np.abs(np.asarray(y) - np.asarray(yd)).max(-1) > 1e-4
    assert diff.mean() < 0.9


def test_aux_loss_uniform_router_is_one_x_weight():
    """With perfectly uniform routing the Switch aux loss is exactly its
    weight: E * (1/E * 1/E) * E = 1, times aux_loss_weight."""
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    _, aux = moe_forward(params, x, cfg)
    # uniform probs: me = 1/E; top-1 ties broken deterministically -> ce
    # concentrated; just assert positive and finite.
    assert float(aux) > 0 and np.isfinite(float(aux))


def test_grads_flow_to_all_weights():
    cfg = _cfg(cf=3.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        y, aux = moe_forward(p, x, cfg)
        return (y ** 2).mean() + aux

    grads = jax.grad(loss)(params)
    for name in ("router", "wi", "wg", "wo"):
        assert float(jnp.abs(grads[name]).max()) > 0, name


@settings(max_examples=20, deadline=None)
@given(seq=st.integers(4, 256))
def test_property_capacity_monotone_and_bounded(seq):
    cfg = _cfg()
    c = capacity(cfg, seq)
    assert 4 <= c <= seq or c == 4
    assert c % 4 == 0 or c == seq
