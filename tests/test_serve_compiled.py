"""Compiled serving engine: per-request token-exactness vs the per-step
oracle (ServingEngine) and vs single-request generation, under staggered
arrivals, mid-stream EOS, slot reuse, and max_seq truncation — plus the
one-bulk-transfer-per-fused-call instrumentation contract."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.launch.serve import generate
from repro.models.model import Model
from repro.serve.compiled import (CompiledServingEngine, decode_state_shardings,
                                  default_buckets)
from repro.serve.engine import Request, ServingEngine

_SETUP_CACHE = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = registry.get_smoke_config(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _SETUP_CACHE[arch] = (cfg, model, params)
    return _SETUP_CACHE[arch]


def _prompts(cfg, lengths, seed=1):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (L,), 0,
                               cfg.vocab_size, dtype=jnp.int32)
            for i, L in enumerate(lengths)]


def _reference_tokens(model, params, prompt, n_new):
    out, _ = generate(model, params, prompt[None, :], n_new)
    return [int(t) for t in out[0]]


# the acceptance pair: one attention-KV arch, one SSM-cache arch
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-2.7b"])
def test_compiled_matches_oracle_and_generate(arch):
    """5 requests of different prompt lengths through 2 slots: the
    compiled engine must produce EXACTLY the oracle engine's tokens AND
    each request's isolated-generation tokens — bucketed (padded) prefill,
    the jitted admission scatter, and the fused decode loop all preserve
    per-request tokens."""
    cfg, model, params = _setup(arch)
    lengths = [9, 17, 5, 12, 8]
    n_new = 6
    prompts = _prompts(cfg, lengths)
    reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=n_new)  # noqa: E731
                    for i, p in enumerate(prompts)]

    oracle = ServingEngine(model, params, max_batch=2, max_seq=64)
    want = oracle.run(reqs())
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                   decode_block=4)
    got = engine.run(reqs())

    for i, p in enumerate(prompts):
        assert got[i] == want[i], (arch, i, "vs oracle")
        assert got[i] == _reference_tokens(model, params, p, n_new), \
            (arch, i, "vs generate")
    # fused-loop contract: one bulk (B, K) transfer per decode call
    assert engine.stats["decode_transfers"] == engine.stats["decode_calls"]
    assert engine.stats["decode_calls"] > 0


def test_sliding_window_arch_with_padded_buckets():
    """gemma3 (sliding-window + full attention layers): bucket padding
    must keep circular window slots arranged by REAL positions."""
    cfg, model, params = _setup("gemma3-1b")
    prompts = _prompts(cfg, [7, 13])
    n_new = 5
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                   decode_block=2, prefill_buckets=(16, 64))
    got = engine.run([Request(rid=i, prompt=p, max_new_tokens=n_new)
                      for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        assert got[i] == _reference_tokens(model, params, p, n_new), i


def test_staggered_arrivals():
    """Requests submitted mid-stream (after decode blocks already ran)
    still come out token-exact; late arrivals wait for a free slot."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [9, 6, 11, 7], seed=3)
    n_new = 8
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                   decode_block=3)
    first = [Request(rid=i, prompt=prompts[i], max_new_tokens=n_new)
             for i in range(2)]
    late = [Request(rid=i, prompt=prompts[i], max_new_tokens=n_new)
            for i in range(2, 4)]
    for r in first:
        engine.submit(r)
    engine.step()                      # decode a block before anyone new
    engine.submit(late[0])
    engine.step()
    engine.submit(late[1])
    steps = 0
    while (engine.active or engine.waiting) and steps < 100:
        engine.step()
        steps += 1
    for i, p in enumerate(prompts):
        want = _reference_tokens(model, params, p, n_new)
        assert (first + late)[i].generated == want, i


def test_mid_stream_eos():
    """A request whose EOS appears mid-block stops exactly where the
    oracle stops (device-side EOS detection + host replay agree)."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompt = _prompts(cfg, [8], seed=5)[0]
    ref = _reference_tokens(model, params, prompt, 6)
    # pick an eos whose FIRST occurrence is past the first token, so it
    # fires inside a decode block rather than at admission
    stop = next(j for j in range(1, len(ref)) if ref[j] not in ref[:j])
    eos = ref[stop]

    oracle = ServingEngine(model, params, max_batch=2, max_seq=32)
    r_o = Request(rid=0, prompt=prompt, max_new_tokens=10, eos_id=eos)
    oracle.run([r_o])
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=32,
                                   decode_block=4)
    r_c = Request(rid=0, prompt=prompt, max_new_tokens=10, eos_id=eos)
    engine.run([r_c])
    assert r_c.generated == r_o.generated
    assert r_c.generated[-1] == eos and len(r_c.generated) == stop + 1
    assert engine.stats["decode_calls"] > 0
    assert r_c.done


def test_eos_as_first_token_finishes_at_admission():
    cfg, model, params = _setup("internlm2-1.8b")
    prompt = _prompts(cfg, [8], seed=6)[0]
    ref = _reference_tokens(model, params, prompt, 2)
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=32,
                                   decode_block=4)
    req = Request(rid=0, prompt=prompt, max_new_tokens=10, eos_id=ref[0])
    engine.run([req])
    assert req.done and req.generated == [ref[0]]
    assert engine.stats["decode_calls"] == 0


def test_slot_reuse_after_free():
    """3 requests through ONE slot: each admission re-prefills the slot's
    cache rows, so request n+1 is token-exact despite inheriting a dirty
    slot (and dirty garbage positions) from request n."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [6, 10, 7], seed=7)
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=32,
                                   decode_block=4)
    results = engine.run([Request(rid=i, prompt=p, max_new_tokens=4)
                          for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        assert results[i] == _reference_tokens(model, params, p, 4), i
    assert engine.active == 0 and not engine.waiting


def test_admission_chain_when_request_finishes_at_admission():
    """A request that finishes AT admission (budget 1) must not strand the
    requests queued behind it: its slot frees inside the same admission
    pass. (Regression: the free-slot list was computed once per pass, so
    the follow-up request waited forever on one-slot engines — on both
    engines.)"""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [6, 8, 7], seed=15)
    for cls in (CompiledServingEngine, ServingEngine):
        kw = {"decode_block": 4} if cls is CompiledServingEngine else {}
        engine = cls(model, params, max_batch=1, max_seq=32, **kw)
        reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=5),
                Request(rid=1, prompt=prompts[1], max_new_tokens=1),
                Request(rid=2, prompt=prompts[2], max_new_tokens=5)]
        results = engine.run(reqs, max_steps=200)
        assert all(r.done for r in reqs), cls.__name__
        assert results[1] == _reference_tokens(model, params, prompts[1],
                                               1), cls.__name__
        assert results[2] == _reference_tokens(model, params, prompts[2],
                                               5), cls.__name__


def test_max_seq_truncation():
    """A request that would run past max_seq-1 truncates at exactly the
    oracle's stopping point (position check after the increment)."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompt = _prompts(cfg, [10], seed=9)[0]

    oracle = ServingEngine(model, params, max_batch=1, max_seq=16)
    r_o = Request(rid=0, prompt=prompt, max_new_tokens=50)
    oracle.run([r_o])
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=16,
                                   decode_block=4)
    r_c = Request(rid=0, prompt=prompt, max_new_tokens=50)
    engine.run([r_c])
    assert r_c.generated == r_o.generated
    assert len(r_c.generated) < 50     # truncated, not budget-stopped
    assert r_c.done


def test_decode_block_size_invariance():
    """K is a throughput knob, not a semantics knob: K=1 and K=5 produce
    identical tokens for the same workload."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [9, 12], seed=11)

    def run(block):
        engine = CompiledServingEngine(model, params, max_batch=2,
                                       max_seq=48, decode_block=block)
        return engine.run([Request(rid=i, prompt=p, max_new_tokens=7)
                           for i, p in enumerate(prompts)])

    assert run(1) == run(5)


def test_categorical_sampling_runs():
    """Sampled decode (device-side categorical) produces the right token
    counts and stays reproducible for a fixed engine rng."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [8, 6], seed=13)

    def run():
        engine = CompiledServingEngine(
            model, params, max_batch=2, max_seq=48, decode_block=4,
            sample="categorical", temperature=0.8,
            rng=jax.random.PRNGKey(42))
        return engine.run([Request(rid=i, prompt=p, max_new_tokens=5)
                           for i, p in enumerate(prompts)])

    a, b = run(), run()
    assert a == b
    assert all(len(v) == 5 for v in a.values())
    assert all(0 <= t < cfg.vocab_size for v in a.values() for t in v)


@pytest.mark.parametrize("arch", ["minicpm3-4b", "zamba2-7b"])
def test_padded_prefill_exact_remaining_cache_families(arch):
    """The engine tests cover dense, SSM, and sliding-window bucketed
    prefill end-to-end; this pins the remaining cache families (MLA
    latent, hybrid shared-attn + mamba): padded prefill with length= must
    match unpadded to float-reassociation tolerance (padding introduces
    no new VALUES, but XLA may re-group the same sums) and be
    token-exact through decode."""
    import numpy as np
    cfg, model, params = _setup(arch)
    S, P, max_seq = 9, 16, 48
    prompt = _prompts(cfg, [S], seed=17)[0][None, :]
    padded = jnp.pad(prompt, ((0, 0), (0, P - S)))
    lu, cu = model.prefill(params, prompt, cache_len=max_seq)
    lp, cp = jax.jit(
        lambda p, t, L: model.prefill(p, t, cache_len=max_seq, length=L))(
            params, padded, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lu), np.asarray(lp), atol=1e-5,
                               rtol=1e-5)
    assert int(jnp.argmax(lu)) == int(jnp.argmax(lp))
    tok = jnp.argmax(lu, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        l_u, cu = model.decode(params, cu, tok, jnp.array([S + i]))
        l_p, cp = model.decode(params, cp, tok, jnp.array([S + i]))
        assert int(jnp.argmax(l_u)) == int(jnp.argmax(l_p)), (arch, i)
        tok = jnp.argmax(l_u, -1)[:, None].astype(jnp.int32)


def test_decode_state_shardings_places_slots_on_data():
    """The multi-host placement helper: dense cache leaves sharded on
    their cache_batch_dim, slot vectors (and block tables) on the batch
    dim, rng replicated."""
    cfg, model, params = _setup("internlm2-1.8b")
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    engine = CompiledServingEngine(model, params, max_batch=8, max_seq=32,
                                   kv_layout="dense")
    sh = decode_state_shardings(mesh, engine.state)
    P = jax.sharding.PartitionSpec
    assert sh.tokens.spec == P("data") and sh.remaining.spec == P("data")
    assert sh.rng.spec == P()
    flat = jax.tree_util.tree_flatten(sh.cache)[0]
    assert flat and all(s.mesh == mesh for s in flat)
    # the stacked-units KV leaf carries units first, slots second
    k = engine.state.cache["units"]["0"]["a"]["k"]
    k_sh = jax.tree_util.tree_flatten(sh.cache["units"]["0"]["a"])[0][0]
    assert k.shape[1] == 8
    assert k_sh.spec == P(*([None, "data"] + [None] * (k.ndim - 2)))


def test_decode_state_shardings_places_pages_on_data():
    """Paged layout: pool leaves shard their PAGE dim (page_pool_dim) on
    data — pages, not slots, are the unit of resident KV state — and the
    block tables shard like every other per-slot vector."""
    cfg, model, params = _setup("internlm2-1.8b")
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    engine = CompiledServingEngine(model, params, max_batch=8, max_seq=32,
                                   kv_layout="paged", page_size=16,
                                   n_pages=16)
    assert engine.kv_layout == "paged"
    sh = decode_state_shardings(mesh, engine.state)
    P = jax.sharding.PartitionSpec
    assert sh.block_tables.spec == P("data", None)
    pool = engine.state.cache["units"]["0"]["p"]["k"]
    pool_sh = jax.tree_util.tree_flatten(sh.cache["units"]["0"]["p"])[0][0]
    # (n_units, n_pages, page_size, KVH, Dh): pages on data, rest local
    assert pool.shape[1:3] == (16, 16)
    assert pool_sh.spec == P(*([None, "data"] + [None] * (pool.ndim - 2)))


def test_oversize_prompt_rejected_clearly():
    """A prompt longer than max_seq can never fit the engine cache; both
    engines must reject it at submit() with a clear error instead of an
    opaque XLA shape failure inside the admission scatter."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompt = _prompts(cfg, [30], seed=19)[0]
    for cls in (CompiledServingEngine, ServingEngine):
        engine = cls(model, params, max_batch=1, max_seq=24)
        with pytest.raises(ValueError, match="cannot fit the engine cache"):
            engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))


def test_default_buckets_shape():
    assert default_buckets(256) == (16, 32, 64, 128, 256)
    assert default_buckets(96) == (16, 32, 64, 96)
    assert default_buckets(16) == (16,)


# ---------------------------------------------------------------------------
# prefill bucket capping (regression: silent per-length recompiles)
# ---------------------------------------------------------------------------

def test_capped_buckets_complete_to_max_seq_and_count_compiles():
    """Custom buckets capped below max_seq used to fall back to
    EXACT-LENGTH prefill for longer prompts — one silent compile per
    distinct prompt length, never counted in stats['prefill_compiles'].
    Construction must append max_seq to the bucket set, and every
    post-warmup prefill compile must be counted."""
    cfg, model, params = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=32,
                                   decode_block=2, prefill_buckets=(8,))
    assert engine.buckets == (8, 32)
    for L in (5, 9, 11, 13):           # 3 distinct lengths above bucket 8
        engine.run([Request(rid=L, prompt=_prompts(cfg, [L], seed=L)[0],
                            max_new_tokens=2)])
    # 2 bucket programs total — NOT 1 + one per distinct long length
    assert engine.stats["prefill_compiles"] == 2
    # buckets beyond max_seq are dropped, not compiled
    e2 = CompiledServingEngine(model, params, max_batch=1, max_seq=32,
                               prefill_buckets=(8, 64, 128))
    assert e2.buckets == (8, 32)


def test_warmup_counts_each_bucket_once():
    cfg, model, params = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=32,
                                   decode_block=2, prefill_buckets=(8, 16))
    engine.warmup()
    assert engine.stats["prefill_compiles"] == len(engine.buckets) == 3
    engine.run([Request(rid=0, prompt=_prompts(cfg, [9], seed=2)[0],
                        max_new_tokens=2)])
    # serving reuses warmed buckets: no new compiles counted
    assert engine.stats["prefill_compiles"] == 3


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------

def test_kv_layout_auto_resolution():
    """auto -> paged iff the model has pageable (full-attention GQA)
    layers; explicitly requesting paged on a pool-less model is an error,
    not a silent dense fallback."""
    _, attn_model, attn_params = _setup("internlm2-1.8b")
    _, ssm_model, ssm_params = _setup("mamba2-2.7b")
    e = CompiledServingEngine(attn_model, attn_params, max_seq=32)
    assert e.kv_layout == "paged" and e.state.block_tables.shape == (4, 2)
    e = CompiledServingEngine(ssm_model, ssm_params, max_seq=32)
    assert e.kv_layout == "dense" and e.state.block_tables.shape == (4, 0)
    with pytest.raises(ValueError, match="pageable"):
        CompiledServingEngine(ssm_model, ssm_params, max_seq=32,
                              kv_layout="paged")


def _run_engine(model, params, reqs, **kw):
    engine = CompiledServingEngine(model, params, **kw)
    out = engine.run(reqs)
    return out, engine


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-1b",
                                  "zamba2-7b"])
def test_paged_matches_dense_across_cache_families(arch):
    """Tentpole exactness: the paged engine's tokens are identical to the
    dense engine's on every pageable family — pure GQA, mixed
    sliding-window + global (only globals paged), and hybrid shared-attn
    over mamba (only the shared block paged)."""
    cfg, model, params = _setup(arch)
    lengths = [9, 17, 5, 12, 8]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=6)  # noqa: E731
                  for i, p in enumerate(_prompts(cfg, lengths))]
    dense, _ = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                           decode_block=4, kv_layout="dense")
    paged, ep = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                            decode_block=4, kv_layout="paged", page_size=16)
    assert paged == dense
    assert ep.stats["decode_transfers"] == ep.stats["decode_calls"]


def test_paged_staggered_eos_and_slot_reuse_match_oracle():
    """Paged vs the per-step oracle under the adversarial schedule: late
    arrivals into reused (dirty) slots, a mid-block EOS, budgets of
    different sizes — all with page recycling in between."""
    cfg, model, params = _setup("internlm2-1.8b")
    prompts = _prompts(cfg, [9, 6, 11, 7, 5], seed=3)
    ref0 = _reference_tokens(model, params, prompts[2], 3)
    eos = ref0[2]                    # fires mid-decode for request 2
    mk = lambda: [  # noqa: E731
        Request(rid=0, prompt=prompts[0], max_new_tokens=8),
        Request(rid=1, prompt=prompts[1], max_new_tokens=3),
        Request(rid=2, prompt=prompts[2], max_new_tokens=9, eos_id=eos),
        Request(rid=3, prompt=prompts[3], max_new_tokens=7),
        Request(rid=4, prompt=prompts[4], max_new_tokens=5)]

    oracle = ServingEngine(model, params, max_batch=2, max_seq=64)
    want = oracle.run(mk())
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                   decode_block=3, kv_layout="paged",
                                   page_size=16)
    reqs = mk()
    engine.submit(reqs[0])
    engine.submit(reqs[1])
    engine.step()
    for r in reqs[2:]:
        engine.submit(r)
        engine.step()
    steps = 0
    while (engine.active or engine.waiting) and steps < 100:
        engine.step()
        steps += 1
    for r in reqs:
        assert r.generated == want[r.rid], r.rid
    # every page returned to the pool once the workload drained
    assert len(engine._free_pages) == engine.n_pages - 1
    assert not any(engine.slot_pages)
    assert not engine._host_bt.any()


def test_paged_int8_token_exact_trio():
    """kv_cache_dtype='int8' on the paged pool: paged-int8, dense-int8
    and the int8 per-step oracle all emit identical greedy tokens (same
    per-(token, head) quantization everywhere — layout changes nothing)."""
    import dataclasses
    cfg, model, params = _setup("internlm2-1.8b")
    lengths = [9, 14, 6]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=6)  # noqa: E731
                  for i, p in enumerate(_prompts(cfg, lengths, seed=21))]
    paged, ep = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                            decode_block=4, kv_layout="paged",
                            kv_cache_dtype="int8")
    dense, ed = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                            decode_block=4, kv_layout="dense",
                            kv_cache_dtype="int8")
    int8_model = Model(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    oracle = ServingEngine(int8_model, params, max_batch=2, max_seq=64)
    want = oracle.run(mk())
    assert paged == dense == want
    # the int8 pool is the footprint win at equal token capacity vs the
    # f32 dense layout it replaces (int8 values + f32 per-token scales)
    f32 = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                kv_layout="dense")
    assert ep.cache_bytes() < f32.cache_bytes()
    assert ep.stats["decode_transfers"] == ep.stats["decode_calls"]


def test_paged_small_pool_defers_admission_not_correctness():
    """A pool far smaller than slots x max_seq forces head-of-line page
    waits; tokens must still be exact and the reservation invariant means
    mid-decode growth never exhausts the pool."""
    cfg, model, params = _setup("internlm2-1.8b")
    lengths = [9, 17, 5, 12, 8]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=6)  # noqa: E731
                  for i, p in enumerate(_prompts(cfg, lengths))]
    dense, _ = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                           decode_block=4, kv_layout="dense")
    # 2 allocatable pages of 16 tokens vs 2 slots x 64: the 17-token
    # prompt reserves both pages, so a second request must wait
    tiny, et = _run_engine(model, params, mk(), max_batch=2, max_seq=64,
                           decode_block=4, kv_layout="paged", page_size=16,
                           n_pages=3)
    assert tiny == dense
    assert et.stats["admit_page_waits"] > 0
    assert len(et._free_pages) == et.n_pages - 1


def test_paged_rejects_unfittable_request():
    """A request whose worst case exceeds the whole pool can never admit:
    submit() must fail loudly instead of deadlocking the queue."""
    cfg, model, params = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, params, max_batch=2, max_seq=64,
                                   kv_layout="paged", page_size=16,
                                   n_pages=3)
    with pytest.raises(ValueError, match="pages"):
        engine.submit(Request(rid=0, prompt=_prompts(cfg, [17])[0],
                              max_new_tokens=40))
