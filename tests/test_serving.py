"""Batched generation loop: greedy decode consistency + cache reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.serve import generate
from repro.models.model import Model


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-2.7b",
                                  "gemma3-1b"])
def test_generate_matches_manual_decode(arch):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S, T = 2, 24, 4
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                 dtype=jnp.int32)
    out, stats = generate(model, params, prompts, T)
    assert out.shape == (B, T)
    assert stats["tokens_per_s"] > 0

    # manual loop must produce identical tokens
    logits, cache = model.prefill(params, prompts, cache_len=S + T)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = []
    for i in range(T):
        toks.append(tok)
        logits, cache = model.decode(params, cache, tok, S + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.concatenate(toks, 1)))


def test_generate_vlm_and_audio_stubs():
    for arch, extra_key, shape in [
            ("qwen2-vl-72b", "vision_embeds", lambda c: (2, c.n_vision_tokens, c.d_model)),
            ("whisper-base", "frames", lambda c: (2, c.encoder_seq, c.d_model))]:
        cfg = registry.get_smoke_config(arch)
        model = Model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        prompts = jax.random.randint(key, (2, 16), 0, cfg.vocab_size,
                                     dtype=jnp.int32)
        extras = {extra_key: jax.random.normal(key, shape(cfg), model.dtype)}
        out, _ = generate(model, params, prompts, 3, extras=extras)
        assert out.shape == (2, 3)
