"""SWAP algorithm invariants: averaging, schedules, ensemble equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig, ScheduleConfig,
                                SWAPConfig)
from repro.core.adapters import LMAdapter
from repro.core.averaging import StreamingAverage, average_list, average_stacked
from repro.core.schedules import schedule_fn
from repro.core.swap import SWAP, _stack_bundles
from repro.data.pipeline import Loader, make_markov_lm
from repro.train.loop import stack_host_batches
from repro.train.precision import default_scale_state, stack_scale_state


# ---------------------------------------------------------------------------
# averaging
# ---------------------------------------------------------------------------


def _tree(seed, shapes={"a": (5, 3), "b": (7,)}):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {k: jax.random.normal(kk, s)
            for (k, s), kk in zip(shapes.items(), ks)}


def test_average_stacked_equals_list():
    trees = [_tree(i) for i in range(4)]
    a1 = average_list(trees)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)
    a2 = average_stacked(stacked)
    for k in a1:
        np.testing.assert_allclose(np.asarray(a1[k]), np.asarray(a2[k]),
                                   atol=1e-6)


def test_streaming_average_equals_mean():
    trees = [_tree(i) for i in range(5)]
    s = StreamingAverage()
    for t in trees:
        s.add(t)
    want = average_list(trees)
    for k in want:
        np.testing.assert_allclose(np.asarray(s.value()[k]),
                                   np.asarray(want[k]), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(w=st.integers(2, 8), seed=st.integers(0, 100))
def test_property_average_within_hull(w, seed):
    """The averaged model is inside the convex hull of worker models:
    coordinate-wise min <= avg <= max (basic sanity of phase 3)."""
    trees = [_tree(seed + i) for i in range(w)]
    avg = average_list(trees)
    for k in avg:
        stack = np.stack([np.asarray(t[k]) for t in trees])
        assert (np.asarray(avg[k]) <= stack.max(0) + 1e-6).all()
        assert (np.asarray(avg[k]) >= stack.min(0) - 1e-6).all()


def test_average_of_identical_models_is_identity():
    t = _tree(0)
    avg = average_list([t, t, t])
    for k in t:
        np.testing.assert_allclose(np.asarray(avg[k]), np.asarray(t[k]),
                                   atol=1e-6)


def test_recompute_bn_stats_weights_by_batch_size():
    """Regression: aggregation must be batch-size-weighted, not a plain
    mean over batches — a short final batch would otherwise pull the
    recomputed statistics off the true one-pass values."""
    from repro.core.averaging import recompute_bn_stats

    def stats_fn(params, batch):
        x = batch["x"]
        return {"bn": {"mean": jnp.mean(x), "var": jnp.var(x)}}

    full = jnp.arange(6.0)                       # batch of 6
    tail = jnp.asarray([30.0, 60.0])             # short tail batch of 2
    out = recompute_bn_stats(stats_fn, {}, [{"x": full}, {"x": tail}])
    want_mean = (6 * float(jnp.mean(full)) + 2 * float(jnp.mean(tail))) / 8
    want_var = (6 * float(jnp.var(full)) + 2 * float(jnp.var(tail))) / 8
    np.testing.assert_allclose(float(out["bn"]["mean"]), want_mean,
                               rtol=1e-6)
    np.testing.assert_allclose(float(out["bn"]["var"]), want_var, rtol=1e-6)
    # an unweighted mean over the two batches would give a different value
    assert abs(want_mean - (float(jnp.mean(full))
                            + float(jnp.mean(tail))) / 2) > 1.0


def test_recompute_bn_stats_empty_iterable_raises():
    """Silently returning nothing would leave a served BN model on stale
    pre-average statistics."""
    from repro.core.averaging import recompute_bn_stats
    with pytest.raises(ValueError, match="no batches"):
        recompute_bn_stats(lambda p, b: {}, {}, [])


def test_recompute_bn_stats_no_array_leaves_raises():
    from repro.core.averaging import recompute_bn_stats
    with pytest.raises(ValueError, match="batch size"):
        recompute_bn_stats(lambda p, b: {"m": jnp.float32(0)}, {},
                           [{"seed": 3}])


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_warmup_linear_shape():
    fn = schedule_fn(ScheduleConfig(kind="warmup_linear", peak_lr=1.0,
                                    warmup_steps=10, total_steps=100,
                                    end_lr=0.0))
    assert float(fn(0)) == 0.0
    np.testing.assert_allclose(float(fn(10)), 1.0, atol=1e-6)
    assert float(fn(5)) == pytest.approx(0.5)
    assert float(fn(100)) == pytest.approx(0.0, abs=1e-6)


def test_cyclic_resets_each_cycle():
    fn = schedule_fn(ScheduleConfig(kind="cyclic", peak_lr=0.5, min_lr=0.1,
                                    cycle_steps=10))
    assert float(fn(0)) == pytest.approx(0.5)
    assert float(fn(10)) == pytest.approx(0.5)   # cycle restart
    assert float(fn(9)) < float(fn(0))


@settings(max_examples=20, deadline=None)
@given(warm=st.integers(1, 20), total=st.integers(30, 200),
       peak=st.floats(1e-4, 2.0))
def test_property_schedule_bounded(warm, total, peak):
    """LR never exceeds peak and never goes negative, for any step."""
    fn = schedule_fn(ScheduleConfig(kind="warmup_cosine", peak_lr=peak,
                                    warmup_steps=warm, total_steps=total))
    steps = np.arange(0, total + 50)
    lrs = np.array([float(fn(s)) for s in steps])
    assert (lrs <= peak + 1e-6).all()
    assert (lrs >= -1e-9).all()


# ---------------------------------------------------------------------------
# phase-2 ensemble semantics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_setup():
    cfg = registry.get_smoke_config("internlm2-1.8b")
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=256, n_test=128,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    return adapter, train


def test_ensemble_step_equals_independent_runs(lm_setup):
    """The vmapped worker ensemble must be EXACTLY independent training:
    running W workers via vmap == running each sequentially. This is the
    code-level form of the paper's 'no synchronization in phase 2'."""
    adapter, train = lm_setup
    W = 3
    sched = schedule_fn(ScheduleConfig(kind="const", peak_lr=0.05))
    raw_step = adapter.make_train_step(sched)
    loader = Loader(train, 16, seed=7)

    bundle = adapter.init(jax.random.PRNGKey(1))
    # vmapped path
    stacked = _stack_bundles(bundle, W)
    opt_stacked = jax.vmap(adapter.init_opt)(stacked)
    sc_stacked = stack_scale_state(default_scale_state(), W)
    ens = jax.jit(jax.vmap(raw_step, in_axes=(0, 0, 0, None, 0)))
    for step in range(3):
        batches = stack_host_batches(loader, step, W)
        stacked, opt_stacked, sc_stacked, _ = ens(
            stacked, opt_stacked, batches, step, sc_stacked)

    # sequential path
    step_fn = jax.jit(raw_step)
    for w in range(W):
        b = bundle
        o = adapter.init_opt(b)
        sc = default_scale_state()
        for step in range(3):
            b, o, sc, _ = step_fn(b, o, loader.batch(step, worker=w),
                                  step, sc)
        got = jax.tree_util.tree_map(lambda a: np.asarray(a[w]),
                                     stacked["params"])
        for (p1, l1), (p2, l2) in zip(
                jax.tree_util.tree_flatten_with_path(got)[0],
                jax.tree_util.tree_flatten_with_path(b["params"])[0]):
            np.testing.assert_allclose(l1, np.asarray(l2), atol=1e-5,
                                       rtol=1e-4)


def test_workers_diverge_with_different_data(lm_setup):
    """Phase-2 stochasticity: different data orders => different weights."""
    adapter, train = lm_setup
    cfg_swap = SWAPConfig(
        n_workers=2,
        phase1=PhaseConfig(batch_size=64, max_steps=2,
                           schedule=ScheduleConfig(kind="const", peak_lr=0.1)),
        phase2=PhaseConfig(batch_size=16, max_steps=3,
                           schedule=ScheduleConfig(kind="const", peak_lr=0.05)))
    test_loader = Loader(train, 64)
    res = SWAP(adapter, cfg_swap, train, test_loader).run(
        jax.random.PRNGKey(0))
    stacked = res["stacked_params"]
    diffs = jax.tree_util.tree_map(
        lambda a: float(jnp.abs(a[0] - a[1]).max()), stacked)
    assert max(jax.tree_util.tree_leaves(diffs)) > 1e-6
