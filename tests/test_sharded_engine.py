"""Sharded-jit phase-2 engine (EpochRunner engine="sharded"): must be
bitwise-identical to the plain-vmap oracle on the same worker mesh, lower
with zero cross-worker collectives, and reject invalid configurations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, OptimizerConfig, ScheduleConfig
from repro.core.adapters import LMAdapter
from repro.core.schedules import schedule_fn
from repro.core.swap import _stack_bundles
from repro.data.pipeline import Loader, make_markov_lm
from repro.dist.sharding import (assert_no_cross_worker_collectives,
                                 ensemble_shardings)
from repro.train.loop import EpochRunner, stack_train_state

W = 2
PER_WORKER = 4  # data=2 x model=2 inside each worker block


def tiny_lm() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=32, attention="gqa",
        dtype="float32", remat=False, scan_layers=False)


def _pieces():
    cfg = tiny_lm()
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=128, n_test=32,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, 16, seed=3)
    step_fn = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="warmup_linear", peak_lr=0.1, warmup_steps=3,
                       total_steps=12)))
    return adapter, loader, step_fn


def _worker_mesh():
    if len(jax.devices()) < W * PER_WORKER:
        pytest.skip(f"needs {W * PER_WORKER} devices "
                    f"(conftest forces 8 on CPU hosts)")
    return jax.make_mesh((W, 2, 2), ("worker", "data", "model"))


def _placed_inputs(adapter, mesh):
    """Ensemble TrainState + worker ids, placed by ensemble_shardings —
    the same physical placement for both engines under test."""
    bundle = adapter.init(jax.random.PRNGKey(0))
    stacked = _stack_bundles(bundle, W)
    state = stack_train_state(stacked, jax.vmap(adapter.init_opt)(stacked), W)
    state = jax.device_put(state, ensemble_shardings(mesh, state))
    workers = jnp.arange(W, dtype=jnp.int32)
    workers = jax.device_put(workers, ensemble_shardings(mesh, workers))
    return state, workers


def test_sharded_engine_bitwise_matches_vmap_oracle():
    """One full epoch chunk through the sharded-jit lowering and through
    plain vmap, from identical placed inputs on the same mesh: every state
    leaf and every stacked metric must match bitwise. This is the oracle
    relationship docs/sharding.md promises — ``spmd_axis_name`` plus pinned
    shardings change the partitioning, never the math."""
    mesh = _worker_mesh()
    adapter, loader, step_fn = _pieces()
    n = loader.steps_per_epoch

    state_v, workers_v = _placed_inputs(adapter, mesh)
    oracle = EpochRunner(step_fn, loader, 0.9, ensemble=True, donate=False)
    ref_state, ref_metrics = oracle.run_chunk(state_v, workers_v, n)

    state_s, workers_s = _placed_inputs(adapter, mesh)
    sharded = EpochRunner(step_fn, loader, 0.9, ensemble=True, mesh=mesh,
                          engine="sharded", donate=False)
    out_state, out_metrics = sharded.run_chunk(state_s, workers_s, n)

    for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                    jax.tree_util.tree_leaves(out_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ref_metrics:
        np.testing.assert_array_equal(np.asarray(ref_metrics[k]),
                                      np.asarray(out_metrics[k]), err_msg=k)


def test_sharded_lowering_has_no_cross_worker_collectives():
    """The compiled sharded-jit chunk on the worker mesh must contain no
    collective whose replica group spans two worker blocks — phase 2 is
    zero-communication by construction."""
    mesh = _worker_mesh()
    adapter, loader, step_fn = _pieces()
    state, workers = _placed_inputs(adapter, mesh)
    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True, mesh=mesh,
                         engine="sharded", donate=False)
    hlo = runner.lower_chunk(
        state, workers, loader.steps_per_epoch).compile().as_text()
    assert_no_cross_worker_collectives(hlo, n_workers=W,
                                       devices_per_worker=PER_WORKER)


def test_sharded_engine_output_keeps_ensemble_sharding():
    """out_shardings pins the advanced state to the same placement as the
    input, so chained chunks never bounce through a replicated layout."""
    mesh = _worker_mesh()
    adapter, loader, step_fn = _pieces()
    state, workers = _placed_inputs(adapter, mesh)
    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True, mesh=mesh,
                         engine="sharded", donate=False)
    out, _ = runner.run_chunk(state, workers, 2)
    want = ensemble_shardings(mesh, out)
    for leaf, sh in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(
                            want, is_leaf=lambda x: hasattr(x, "spec"))):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)


def test_engine_validation_errors():
    adapter, loader, step_fn = _pieces()
    with pytest.raises(ValueError, match="engine must be"):
        EpochRunner(step_fn, loader, 0.9, engine="pmap")
    with pytest.raises(ValueError, match="ensemble"):
        EpochRunner(step_fn, loader, 0.9, engine="sharded")
    with pytest.raises(ValueError, match="worker"):
        EpochRunner(step_fn, loader, 0.9, ensemble=True, engine="sharded")
    no_worker = jax.make_mesh((2, 2), ("data", "model"))
    with pytest.raises(ValueError, match="worker"):
        EpochRunner(step_fn, loader, 0.9, ensemble=True, engine="sharded",
                    mesh=no_worker)
