"""Checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs import registry
from repro.models.model import Model


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.asarray(3)}}
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, tree)
    out = load_pytree(p, tree)
    for (k1, l1), (k2, l2) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert l1.dtype == l2.dtype
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = registry.get_smoke_config("gemma3-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = str(tmp_path / "model.msgpack")
    save_pytree(p, params)
    out = load_pytree(p, params)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, out)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0


def test_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        load_pytree(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_shape_mismatch_raises_clearly(tmp_path):
    """Resuming with a changed config (n_workers, model size) must fail
    with an explicit shape message, not a downstream vmap trace error."""
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones((4, 2))})
    with pytest.raises(ValueError, match="shape"):
        load_pytree(p, {"a": jnp.ones((2, 2))})


def test_save_is_atomic_no_tmp_left_behind(tmp_path):
    import os
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones(3)})
    save_pytree(p, {"a": jnp.zeros(3)})        # overwrite in place
    assert sorted(os.listdir(tmp_path)) == ["ckpt.msgpack"]
    out = load_pytree(p, {"a": jnp.ones(3)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.zeros(3))


# ---------------------------------------------------------------------------
# publish snapshots (the live-serving path: repro.serve.publish)
# ---------------------------------------------------------------------------

def test_publish_crash_between_sidecar_and_snapshot(tmp_path, monkeypatch):
    """Kill the publisher between the sidecar write and the snapshot write:
    the torn generation must be invisible to every consumer — a follower
    keeps serving the previous complete generation."""
    import repro.checkpoint.state as cs
    from repro.serve.publish import PublishFollower

    d = str(tmp_path)
    tpl = {"a": jnp.ones((3,), jnp.float32)}
    cs.save_publish(d, 1, 10, tpl)                       # complete gen 1

    def boom(path, tree):
        raise RuntimeError("killed mid-publish")
    monkeypatch.setattr(cs, "save_pytree", boom)
    with pytest.raises(RuntimeError):
        cs.save_publish(d, 2, 20, {"a": jnp.zeros((3,), jnp.float32)})
    monkeypatch.undo()

    import os
    names = sorted(os.listdir(d))
    assert "publish-gen00000002-step00000020.msgpack.json" in names, \
        "the crash should have happened AFTER the sidecar write"
    assert "publish-gen00000002-step00000020.msgpack" not in names
    # gen 2's stray sidecar is invisible: every consumer sees only gen 1
    assert [p["generation"] for p in cs.list_publishes(d)] == [1]
    assert cs.find_latest_publish(d)["generation"] == 1
    follower = PublishFollower(d, template=tpl)
    gen, params = follower.poll()
    assert gen == 1
    np.testing.assert_array_equal(np.asarray(params["a"]), np.ones(3))
    assert follower.poll() is None
    # a completed retry of the publish becomes visible atomically
    cs.save_publish(d, 2, 20, {"a": jnp.zeros((3,), jnp.float32)})
    gen, params = follower.poll()
    assert gen == 2
    np.testing.assert_array_equal(np.asarray(params["a"]), np.zeros(3))


def test_publish_tmp_debris_never_visible(tmp_path):
    """A stray .tmp from a kill inside atomic_write's write step must not
    surface through the publish listing."""
    from repro.checkpoint.state import (find_latest_publish, list_publishes,
                                        publish_path, save_publish)
    d = str(tmp_path)
    save_publish(d, 3, 30, {"a": jnp.ones(2)})
    debris = publish_path(d, 4, 40) + ".tmp"
    with open(debris, "wb") as f:
        f.write(b"partial bytes")
    assert [p["generation"] for p in list_publishes(d)] == [3]
    assert find_latest_publish(d)["generation"] == 3


def test_find_resume_point_ignores_publish_snapshots(tmp_path):
    """A training resume must NEVER restart from an averaged publish —
    publish files are invisible to list_checkpoints/find_resume_point even
    when they are the newest files in the directory."""
    from repro.checkpoint.io import save_pytree as sp
    from repro.checkpoint.state import (find_resume_point, list_checkpoints,
                                        save_publish)
    d = str(tmp_path)
    save_publish(d, 9, 900, {"a": jnp.ones(2)})
    assert find_resume_point(d) is None                  # publish-only dir
    sp(str(tmp_path / "phase1-step00000040.msgpack"), {"a": jnp.ones(2)})
    rp = find_resume_point(d)
    assert rp is not None and rp["tag"] == "phase1" and rp["step"] == 40
    assert all(c["tag"] != "publish" for c in list_checkpoints(d))
