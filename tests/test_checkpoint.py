"""Checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs import registry
from repro.models.model import Model


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.asarray(3)}}
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, tree)
    out = load_pytree(p, tree)
    for (k1, l1), (k2, l2) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert l1.dtype == l2.dtype
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = registry.get_smoke_config("gemma3-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = str(tmp_path / "model.msgpack")
    save_pytree(p, params)
    out = load_pytree(p, params)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, out)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0


def test_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        load_pytree(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_shape_mismatch_raises_clearly(tmp_path):
    """Resuming with a changed config (n_workers, model size) must fail
    with an explicit shape message, not a downstream vmap trace error."""
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones((4, 2))})
    with pytest.raises(ValueError, match="shape"):
        load_pytree(p, {"a": jnp.ones((2, 2))})


def test_save_is_atomic_no_tmp_left_behind(tmp_path):
    import os
    p = str(tmp_path / "ckpt.msgpack")
    save_pytree(p, {"a": jnp.ones(3)})
    save_pytree(p, {"a": jnp.zeros(3)})        # overwrite in place
    assert sorted(os.listdir(tmp_path)) == ["ckpt.msgpack"]
    out = load_pytree(p, {"a": jnp.ones(3)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.zeros(3))
