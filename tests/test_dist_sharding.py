"""repro.dist beyond the seed spec: mesh context semantics, optimizer-state
mirror determinism, and the end-to-end phase-2 no-cross-worker-collectives
property (positive on the real vmapped ensemble step, negative on a
deliberate cross-worker psum)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import OptimizerConfig, ScheduleConfig
from repro.core.adapters import LMAdapter
from repro.core.schedules import schedule_fn
from repro.core.swap import _stack_bundles
from repro.dist.sharding import (
    assert_no_cross_worker_collectives, ensemble_shardings, get_mesh,
    logical_constraint, param_spec, set_mesh,
)
from repro.train.precision import default_scale_state, stack_scale_state


# ---------------------------------------------------------------------------
# mesh context + logical_constraint
# ---------------------------------------------------------------------------


def test_logical_constraint_identity_without_mesh():
    """With no ambient mesh, logical_constraint returns its input object —
    not a copy, not a traced transform — so bare-CPU model code pays zero."""
    assert get_mesh() is None
    x = jnp.arange(12.0).reshape(3, 4)
    y = logical_constraint(x, ("batch", None))
    assert y is x
    # also the identity inside jit (traces to the traced value itself)
    out = jax.jit(lambda a: logical_constraint(a, ("batch",)))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_logical_constraint_applies_under_mesh():
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    x = jnp.zeros((n * 2, 16))
    with set_mesh(mesh):
        out = jax.jit(lambda a: logical_constraint(a, ("batch",)))(x)
    assert out.sharding.spec == P("data")


def test_set_mesh_is_reentrant():
    n = len(jax.devices())
    m1 = jax.make_mesh((n,), ("data",))
    m2 = jax.make_mesh((n,), ("model",))
    assert get_mesh() is None
    with set_mesh(m1):
        assert get_mesh() is m1
        with set_mesh(m2):
            assert get_mesh() is m2
        assert get_mesh() is m1
    assert get_mesh() is None


# ---------------------------------------------------------------------------
# param_spec determinism across optimizer-state mirrors
# ---------------------------------------------------------------------------


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


_MIRROR_CASES = [
    ("embed/table", (512, 256)),
    ("head/w", (256, 512)),
    ("blocks/attn/wq", (4, 1, 256, 512)),
    ("blocks/mlp/wi", (4, 1, 256, 1024)),
    ("blocks/ln1/scale", (4, 1, 256)),
    ("blocks/moe/wi", (4, 1, 8, 256, 512)),
    ("tail/out/w", (3, 256, 256)),
]


@pytest.mark.parametrize("name,shape", _MIRROR_CASES)
def test_param_spec_deterministic_across_opt_mirrors(name, shape):
    """mu/ nu/ m/ v/ (and nested mu/nu) mirrors resolve to the parameter's
    own spec, and repeated calls are bit-identical (pure function)."""
    base = param_spec(name, shape, _FakeMesh)
    assert param_spec(name, shape, _FakeMesh) == base  # deterministic
    for prefix in ("mu/", "nu/", "m/", "v/", "mu/nu/"):
        assert param_spec(prefix + name, shape, _FakeMesh) == base, \
            f"{prefix + name} diverged from {name}"


def test_param_spec_divisibility_fallback_to_replication():
    # 2 core dims but neither divisible by its mesh axis -> fully replicated
    assert param_spec("blocks/attn/wq", (4, 1, 255, 3), _FakeMesh) == P()
    # embed table with indivisible vocab: model axis dropped
    assert param_spec("embed/table", (512, 3), _FakeMesh) == P()


# ---------------------------------------------------------------------------
# end-to-end: phase-2 ensemble step on a worker mesh
# ---------------------------------------------------------------------------

W = 2          # workers
PER_WORKER = 4  # data=2 x model=2 inside each worker block


def _worker_mesh():
    if len(jax.devices()) < W * PER_WORKER:
        pytest.skip(f"needs {W * PER_WORKER} devices "
                    f"(conftest forces 8 on CPU hosts)")
    return jax.make_mesh((W, 2, 2), ("worker", "data", "model"))


@pytest.fixture(scope="module")
def worker_mesh():
    return _worker_mesh()


def _phase2_compiled(mesh):
    """Compile the REAL phase-2 ensemble step (adapter train step, vmapped
    over the leading worker axis — exactly what SWAP.run jits) with the
    stacked trees placed by ensemble_shardings, and return its HLO."""
    cfg = registry.get_smoke_config("internlm2-1.8b")
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    raw_step = adapter.make_train_step(schedule_fn(
        ScheduleConfig(kind="const")))
    ens_step = jax.vmap(raw_step, in_axes=(0, 0, 0, None, 0))

    bundle = jax.eval_shape(adapter.init, jax.random.PRNGKey(0))
    stacked = jax.eval_shape(lambda b: _stack_bundles(b, W), bundle)
    opt = jax.eval_shape(jax.vmap(adapter.init_opt), stacked)
    batch = {
        "tokens": jax.ShapeDtypeStruct((W, 4, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((W, 4, 16), jnp.int32),
    }
    scale = jax.eval_shape(
        lambda: stack_scale_state(default_scale_state(), W))

    s_sh = ensemble_shardings(mesh, stacked)
    o_sh = ensemble_shardings(mesh, opt)
    b_sh = ensemble_shardings(mesh, batch)
    sc_sh = ensemble_shardings(mesh, scale)
    fn = jax.jit(ens_step, in_shardings=(s_sh, o_sh, b_sh, None, sc_sh),
                 out_shardings=(s_sh, o_sh, sc_sh, None))
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return fn.lower(stacked, opt, batch, step, scale).compile()


def test_phase2_ensemble_step_has_no_cross_worker_collectives(worker_mesh):
    compiled = _phase2_compiled(worker_mesh)
    assert_no_cross_worker_collectives(compiled.as_text(), n_workers=W,
                                       devices_per_worker=PER_WORKER)


def test_cross_worker_psum_is_rejected(worker_mesh):
    """Negative control: a step that psums over the worker axis must trip
    the assert — proves the check can actually see a violation."""
    from jax.experimental.shard_map import shard_map

    def bad_step(x):
        return jax.lax.psum(x, "worker")

    f = shard_map(bad_step, mesh=worker_mesh,
                  in_specs=P("worker"), out_specs=P())
    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((W * PER_WORKER, 1), jnp.float32)
    ).compile().as_text()
    with pytest.raises(AssertionError, match="spans workers"):
        assert_no_cross_worker_collectives(hlo, n_workers=W,
                                           devices_per_worker=PER_WORKER)


def test_cross_worker_collective_permute_is_rejected():
    """collective-permute carries source_target_pairs, not replica_groups —
    a cross-worker permute must still trip the assert."""
    hlo = ("%cp = f32[4]{0} collective-permute(%x), "
           "source_target_pairs={{0,1},{2,4},{3,6}}")
    with pytest.raises(AssertionError, match="spans workers"):
        assert_no_cross_worker_collectives(hlo, n_workers=2,
                                           devices_per_worker=4)
    ok = ("%cp = f32[4]{0} collective-permute(%x), "
          "source_target_pairs={{0,1},{1,2},{4,5}}")
    assert assert_no_cross_worker_collectives(
        ok, n_workers=2, devices_per_worker=4) == 3


def test_empty_replica_groups_means_all_devices():
    """replica_groups={} is XLA's 'one group of ALL replicas' — with more
    than one worker that is by definition a cross-worker sync."""
    hlo = "%ar = f32[4]{0} all-reduce(%x), replica_groups={}"
    with pytest.raises(AssertionError, match="spans workers"):
        assert_no_cross_worker_collectives(hlo, n_workers=2,
                                           devices_per_worker=2)
    # degenerate single-worker deployment: nothing to cross
    assert_no_cross_worker_collectives(hlo, n_workers=1,
                                       devices_per_worker=4)


def test_collective_bytes_async_start_counts_result_only():
    from repro.dist.sharding import collective_bytes

    hlo = ("%ars = (f32[128,256]{1,0}, f32[128,256]{1,0}) "
           "all-reduce-start(f32[128,256]{1,0} %x), "
           "replica_groups={{0,1}}\n"
           "%ard = f32[128,256]{1,0} all-reduce-done(%ars)\n"
           "%ags = (bf16[2,64]{1,0}, bf16[8,64]{1,0}) "
           "all-gather-start(bf16[2,64]{1,0} %y), replica_groups={{0,1,2,3}}")
    out = collective_bytes(hlo)
    # operand half of the -start tuple must not be double counted, and the
    # -done form must not count at all
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 8 * 64 * 2


def test_ensemble_shardings_put_worker_axis_first(worker_mesh):
    tree = {"w": jax.ShapeDtypeStruct((W, 6, 8), jnp.float32),
            "scalar": jax.ShapeDtypeStruct((), jnp.float32),
            "odd": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    sh = ensemble_shardings(worker_mesh, tree)
    # _resolve pads to the leaf's full rank; only the leading dim is named
    assert sh["w"].spec == P("worker", None, None)
    assert sh["scalar"].spec == P()
    # leading dim not divisible by W -> replicated, never an error
    assert sh["odd"].spec == P()
