"""Continuous-batching engine: token-exact vs single-request generation,
slot reuse, per-request positions."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.launch.serve import generate
from repro.models.model import Model
from repro.serve.engine import Request, ServingEngine


def _setup(arch):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_tokens(model, params, prompt, n_new):
    out, _ = generate(model, params, prompt[None, :], n_new)
    return [int(t) for t in out[0]]


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-2.7b",
                                  "minicpm3-4b", "gemma3-1b"])
def test_engine_matches_single_request(arch):
    """5 requests of different prompt lengths through 2 slots must produce
    EXACTLY the tokens each request gets in isolation — proves slot reuse,
    per-slot positions, and cache re-initialization are sound."""
    cfg, model, params = _setup(arch)
    key = jax.random.PRNGKey(1)
    lengths = [9, 17, 5, 12, 8]
    n_new = 6
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (L,), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
               for i, L in enumerate(lengths)]

    engine = ServingEngine(model, params, max_batch=2, max_seq=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    results = engine.run(reqs)

    for i, p in enumerate(prompts):
        want = _reference_tokens(model, params, p, n_new)
        assert results[i] == want, (arch, i)


def test_slots_are_reused():
    cfg, model, params = _setup("internlm2-1.8b")
    engine = ServingEngine(model, params, max_batch=1, max_seq=32)
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (6,), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
               for i in range(3)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    results = engine.run(reqs)
    assert all(len(v) == 3 for v in results.values())
    assert engine.active == 0 and not engine.waiting


def test_property_random_loads_token_exact():
    """Hypothesis-style property over random request mixes: any (lengths,
    new-token counts, slot count) combination is token-exact vs isolated
    generation."""
    from hypothesis import given, settings, strategies as st

    cfg, model, params = _setup("internlm2-1.8b")

    @settings(max_examples=5, deadline=None)
    @given(lengths=st.lists(st.integers(3, 20), min_size=1, max_size=4),
           n_new=st.integers(1, 5), slots=st.integers(1, 3))
    def prop(lengths, n_new, slots):
        key = jax.random.PRNGKey(sum(lengths))
        prompts = [jax.random.randint(jax.random.fold_in(key, i), (L,), 0,
                                      cfg.vocab_size, dtype=jnp.int32)
                   for i, L in enumerate(lengths)]
        engine = ServingEngine(model, params, max_batch=slots, max_seq=48)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new)
                for i, p in enumerate(prompts)]
        results = engine.run(reqs)
        for i, p in enumerate(prompts):
            assert results[i] == _reference_tokens(model, params, p, n_new)

    prop()


def test_eos_stops_early():
    cfg, model, params = _setup("internlm2-1.8b")
    prompt = jax.random.randint(jax.random.PRNGKey(0), (8,), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    # find the greedy second token and use it as eos
    ref = _reference_tokens(model, params, prompt, 4)
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=10, eos_id=ref[1])
    engine.run([req])
    assert req.generated[-1] == ref[1]
    assert len(req.generated) <= 3


def test_idle_slot_positions_freeze():
    """Regression: step() advanced positions for EVERY slot, so an idle
    slot's position drifted without bound while another slot decoded —
    its garbage writes clamp into cache row max_seq-1, and a later
    admission near the truncation boundary inherited a poisoned row.
    Positions must freeze for slots with no request (mirroring the
    compiled engine's _advance)."""
    cfg, model, params = _setup("internlm2-1.8b")
    max_seq = 16
    engine = ServingEngine(model, params, max_batch=2, max_seq=max_seq)
    key = jax.random.PRNGKey(3)
    p_long = jax.random.randint(key, (6,), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    long_req = Request(rid=0, prompt=p_long, max_new_tokens=max_seq)
    engine.submit(long_req)
    # slot 1 idles the whole time slot 0 decodes toward max_seq-1; with
    # the drift bug its position passes max_seq-1 within these steps
    while not long_req.done:
        engine.step()
        assert int(engine.positions[1]) == 0, \
            "idle slot position drifted while another slot decoded"
    # a fresh request admitted into the idle slot must be token-exact
    # right up against the truncation boundary (row max_seq-1 clean)
    p2 = jax.random.randint(jax.random.fold_in(key, 1), (6,), 0,
                            cfg.vocab_size, dtype=jnp.int32)
    late = Request(rid=1, prompt=p2, max_new_tokens=max_seq)
    engine.run([late])
    # truncation allows exactly max_seq - S tokens (stop at row max_seq-1)
    want = _reference_tokens(model, params, p2, max_seq - 6)
    assert late.generated == want
