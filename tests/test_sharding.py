"""Sharding rules + HLO collective parsing."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.dist.sharding import (
    assert_no_cross_worker_collectives, batch_shardings, collective_bytes,
    param_shardings, param_spec, parse_replica_groups,
)
from repro.models.model import Model


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_param_spec_rules(mesh):
    # base (unstacked) params — embedding/head keep their contraction dim
    # OFF the data axis (see §Perf iter 1 in EXPERIMENTS.md)
    assert param_spec("embed/table", (512, 256), mesh) == P(None, "model")
    assert param_spec("head/w", (256, 512), mesh) == P(None, "model")
    # stacked under scanned blocks: two leading None dims
    spec = param_spec("blocks/attn/wq", (4, 1, 256, 512), mesh)
    assert spec == P(None, None, "data", "model")
    # optimizer-state mirror gets the same spec
    spec2 = param_spec("mu/blocks/attn/wq", (4, 1, 256, 512), mesh)
    assert spec == spec2
    # norm scales replicate
    assert param_spec("blocks/ln1/scale", (4, 1, 256), mesh) == P()


def test_divisibility_fallback():
    m = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # dims that don't divide by the axis group size stay replicated
    big = jax.make_mesh((1, 1), ("data", "model"),
                        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = param_spec("attn/wk", (256, 3), big)   # 3 kv-dim indivisible by 1?
    # axis size 1 divides everything; use a fake larger mesh via resolve
    from repro.dist.sharding import _resolve
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}
    assert _resolve(FakeMesh, ("embed", "heads"), (256, 40)) == P("data", None)
    assert _resolve(FakeMesh, ("embed", "heads"), (256, 64)) == P("data", "model")


def test_all_arch_params_get_shardings(mesh):
    """Every leaf of every smoke arch resolves to a sharding without error,
    and at least the big matmuls are sharded (non-trivial spec)."""
    for arch in registry.ASSIGNED_ARCHS:
        cfg = registry.get_smoke_config(arch)
        model = Model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sh = param_shardings(mesh, shapes)
        assert jax.tree_util.tree_structure(sh) == \
            jax.tree_util.tree_structure(shapes)


def test_batch_shardings(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = batch_shardings(mesh, batch)
    assert sh["tokens"].spec == P("data", None) or \
        sh["tokens"].spec == P()  # 1-device mesh: data axis size 1 divides


HLO_SAMPLE = """
  %ar = f32[16,512]{1,0} all-reduce(f32[16,512]{1,0} %x), replica_groups={{0,1},{2,3}}
  %ag.1 = bf16[4,128]{1,0} all-gather(bf16[2,128]{1,0} %y), replica_groups=[2,2]<=[4]
  %add = f32[16]{0} add(f32[16]{0} %a, f32[16]{0} %b)
"""


def test_collective_bytes_parses_kinds():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 512 * 4
    assert out["all-gather"] == 4 * 128 * 2
    assert "add" not in out and len(out) == 2


def test_parse_replica_groups_list_and_iota():
    groups = parse_replica_groups(HLO_SAMPLE)
    assert [0, 1] in groups and [2, 3] in groups


def test_cross_worker_assertion():
    ok_hlo = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}"
    assert_no_cross_worker_collectives(ok_hlo, n_workers=2,
                                       devices_per_worker=2)
    bad_hlo = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,2}}"
    with pytest.raises(AssertionError):
        assert_no_cross_worker_collectives(bad_hlo, n_workers=2,
                                           devices_per_worker=2)


def test_iota_transpose_groups():
    hlo = "%ar = f32[8]{0} all-reduce(%x), replica_groups=[2,2]<=[2,2]T(1,0)"
    groups = parse_replica_groups(hlo)
    # arange(4).reshape(2,2).T = [[0,2],[1,3]]
    assert [0, 2] in groups and [1, 3] in groups
