"""Chaos suite for the resilience subsystem: every fault is scripted by
``repro.testing.faults`` against a fake clock — worker death mid-phase-2,
a corrupted latest checkpoint, a NaN-loss step, failed publish delivery,
and admission-deadline rejection — and every scenario must end with the
pipeline producing its result, not hanging or crashing. No wall-clock
sleeps anywhere: clocks advance by script, deadlines are checked at
submit/step boundaries, and recovery replays are deterministic."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import ChecksumError
from repro.checkpoint.state import (Checkpointer, find_latest_publish,
                                    find_resume_point, list_checkpoints,
                                    load_train_state, read_meta,
                                    save_publish, save_train_state,
                                    state_step, verify_snapshot)
from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig, ScheduleConfig,
                                SWAPConfig)
from repro.core.adapters import LMAdapter
from repro.core.swap import SGDRun, SWAP
from repro.data.pipeline import Loader, make_markov_lm
from repro.dist.config import DistConfig
from repro.dist.heartbeat import HeartbeatMonitor, HeartbeatWriter
from repro.resilience import (PhaseSupervisor, SupervisorConfig,
                              SupervisorError)
from repro.serve.publish import WeightPublisher
from repro.testing.faults import (FakeClock, FaultPlan,
                                  corrupt_latest_checkpoint, truncate_sidecar)
from repro.train.loop import init_train_state

INF = float("inf")


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------


_LM_CACHE = {}


def _lm_setup(n_train=128, n_test=64):
    key = (n_train, n_test)
    if key not in _LM_CACHE:
        cfg = registry.get_smoke_config("internlm2-1.8b")
        data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=n_train,
                              n_test=n_test, seq_len=16)
        train = {"tokens": data["train_tokens"],
                 "labels": data["train_labels"]}
        test_loader = Loader({"tokens": data["test_tokens"],
                              "labels": data["test_labels"]}, 32)
        adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
        _LM_CACHE[key] = (cfg, adapter, train, test_loader)
    return _LM_CACHE[key]


def _swap_cfg(n_workers=4, phase2_steps=4, **kw):
    return SWAPConfig(
        n_workers=n_workers,
        phase1=PhaseConfig(batch_size=32, max_steps=2,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.1)),
        phase2=PhaseConfig(batch_size=16, max_steps=phase2_steps,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.05)),
        bn_recompute_batch_size=64, **kw)


def _tiny_state(step=0, value=1.0):
    bundle = {"params": {"w": jnp.full((4, 3), value, jnp.float32)},
              "state": {}}
    opt = {"m": jnp.zeros((4, 3), jnp.float32)}
    return init_train_state(bundle, opt, step=step)


def _flip_byte(path):
    with open(path, "rb") as f:
        data = bytearray(f.read())
    data[len(data) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))


# ---------------------------------------------------------------------------
# heartbeat liveness
# ---------------------------------------------------------------------------


def test_fake_clock_is_monotonic():
    clock = FakeClock()
    assert clock() == 0.0
    clock.advance(2.5)
    assert clock() == 2.5
    with pytest.raises(ValueError, match="rewind"):
        clock.advance(-1.0)


def test_heartbeat_writer_interval_and_beacon(tmp_path):
    clock = FakeClock()
    w = HeartbeatWriter(str(tmp_path), 2, interval_s=5.0, clock=clock)
    assert w.maybe_beat(step=1)
    assert not w.maybe_beat(step=2)          # inside the min interval
    clock.advance(5.0)
    assert w.maybe_beat(step=3)
    with open(w.path) as f:
        rec = json.load(f)
    assert rec == {"worker": 2, "seq": 2, "t": 5.0, "step": 3}


def test_monitor_staleness_liveness_arrivals(tmp_path):
    clock = FakeClock()
    hb = str(tmp_path)
    w0 = HeartbeatWriter(hb, 0, clock=clock)
    w1 = HeartbeatWriter(hb, 1, clock=clock)
    mon = HeartbeatMonitor(hb, 3, timeout_s=4.0, clock=clock)
    w0.beat()
    clock.advance(3.0)
    w1.beat()
    clock.advance(1.0)
    # worker 0: 4s stale (exactly the timeout — still live), worker 1:
    # 1s stale, worker 2: never beat
    assert mon.staleness() == [4.0, 1.0, INF]
    assert mon.live_mask().tolist() == [True, True, False]
    assert mon.dead_among([0, 1, 2]) == [2]
    # staleness-as-lateness, aligned with the order asked for
    assert mon.arrivals([1, 0]) == [1.0, 4.0]
    assert mon.arrivals() == [4.0, 1.0, INF]
    clock.advance(1.0)                       # worker 0 now past the timeout
    assert mon.dead_among([0, 1]) == [0]
    assert mon.arrivals([0, 1]) == [INF, 2.0]


def test_monitor_tolerates_damaged_beacon(tmp_path):
    clock = FakeClock()
    hb = str(tmp_path)
    HeartbeatWriter(hb, 0, clock=clock).beat()
    with open(os.path.join(hb, "hb-worker0.json"), "w") as f:
        f.write('{"worker": 0, "seq"')       # torn out-of-band
    mon = HeartbeatMonitor(hb, 1, timeout_s=1.0, clock=clock)
    assert mon.poll() == {0: None}
    assert not mon.live_mask().any()


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------


def test_checksum_detects_flipped_byte(tmp_path):
    path = str(tmp_path / "phase1-step00000005.msgpack")
    state = _tiny_state(step=5)
    save_train_state(path, state)
    assert verify_snapshot(path)
    restored = load_train_state(path, _tiny_state())
    assert state_step(restored) == 5
    _flip_byte(path)
    assert not verify_snapshot(path)
    with pytest.raises(ChecksumError, match="content checksum"):
        load_train_state(path, _tiny_state())


def test_truncated_sidecar_skipped_with_fallback(tmp_path):
    """Regression (satellite): a sidecar truncated mid-JSON (kill between
    sidecar rename and a later overwrite, disk damage) must not crash
    read_meta or find_resume_point — the snapshot is unverifiable, so the
    previous good one wins."""
    d = str(tmp_path)
    old = os.path.join(d, "phase1-step00000002.msgpack")
    new = os.path.join(d, "phase1-step00000004.msgpack")
    save_train_state(old, _tiny_state(step=2))
    save_train_state(new, _tiny_state(step=4))
    truncate_sidecar(new)
    with pytest.warns(RuntimeWarning, match="unreadable checkpoint sidecar"):
        meta = read_meta(new)
    assert meta.get("_sidecar_corrupt")
    with pytest.warns(RuntimeWarning, match="skipping corrupt checkpoint"):
        pick = find_resume_point(d)
    assert pick is not None and pick["step"] == 2


@pytest.mark.parametrize("mode", ["flip", "truncate"])
def test_resume_point_skips_corrupt_latest(tmp_path, mode):
    d = str(tmp_path)
    save_train_state(os.path.join(d, "phase2-step00000002.msgpack"),
                     _tiny_state(step=2))
    save_train_state(os.path.join(d, "phase2-step00000004.msgpack"),
                     _tiny_state(step=4))
    bad = corrupt_latest_checkpoint(d, mode=mode)
    assert bad.endswith("phase2-step00000004.msgpack")
    with pytest.warns(RuntimeWarning, match="falling back"):
        pick = find_resume_point(d)
    assert pick is not None and pick["step"] == 2
    assert load_train_state(pick["path"], _tiny_state()) is not None


def test_resume_point_none_when_everything_corrupt(tmp_path):
    d = str(tmp_path)
    save_train_state(os.path.join(d, "phase1-step00000001.msgpack"),
                     _tiny_state(step=1))
    corrupt_latest_checkpoint(d)
    with pytest.warns(RuntimeWarning):
        assert find_resume_point(d) is None


def test_prune_never_deletes_last_verified_good(tmp_path):
    d = str(tmp_path)
    writer = Checkpointer(d, keep=10)
    for step in (10, 20, 30):
        writer.save("phase2", _tiny_state(step=step))
    # the two newest snapshots get damaged on disk; a fresh Checkpointer
    # (no in-process verified cache) prunes down to keep=2
    for name in ("phase2-step00000020.msgpack", "phase2-step00000030.msgpack"):
        _flip_byte(os.path.join(d, name))
    Checkpointer(d, keep=2)._prune("phase2")
    steps = [c["step"] for c in list_checkpoints(d)]
    # step 10 would normally be pruned, but it is the only verified-good
    # snapshot left — it must survive so a resume has a fallback
    assert 10 in steps
    assert verify_snapshot(os.path.join(d, "phase2-step00000010.msgpack"))


def test_prune_still_bounds_good_snapshots(tmp_path):
    d = str(tmp_path)
    ckpt = Checkpointer(d, keep=2)
    for step in (10, 20, 30):
        ckpt.save("phase2", _tiny_state(step=step))
    assert [c["step"] for c in list_checkpoints(d)] == [20, 30]


def test_latest_publish_skips_corrupt_generation(tmp_path):
    d = str(tmp_path)
    params = {"w": jnp.ones((3,), jnp.float32)}
    save_publish(d, 1, 10, params)
    p2 = save_publish(d, 2, 20, params)
    _flip_byte(p2)
    with pytest.warns(RuntimeWarning, match="falling back to the previous"):
        latest = find_latest_publish(d)
    assert latest is not None and latest["generation"] == 1


# ---------------------------------------------------------------------------
# supervised phase execution
# ---------------------------------------------------------------------------


def _sgd_phase(max_steps=3):
    _, adapter, train, _ = _lm_setup()
    phase = PhaseConfig(batch_size=16, max_steps=max_steps,
                        schedule=ScheduleConfig(kind="const", peak_lr=0.1))
    run = SGDRun(adapter, phase, train)
    bundle = adapter.init(jax.random.PRNGKey(0))
    return run, run.init_state(bundle)


def _params_finite(state):
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(state.bundle["params"]))


def test_supervisor_exhausts_budget_with_backoff_schedule():
    """A fault that recurs on every replay (data-driven divergence) spends
    the retry budget on the scripted backoff schedule, then fails loudly."""
    run, state = _sgd_phase()

    def always_nan(st, metrics):
        params = jax.tree_util.tree_map(
            lambda a: jnp.full_like(a, jnp.nan)
            if jnp.issubdtype(a.dtype, jnp.inexact) else a,
            st.bundle["params"])
        return st._replace(bundle=dict(st.bundle, params=params)), metrics

    sleeps = []
    sup = PhaseSupervisor(
        SupervisorConfig(max_retries=2, backoff_s=0.5, backoff_factor=2.0),
        sleep=sleeps.append)
    with pytest.warns(RuntimeWarning, match="divergence"):
        with pytest.raises(SupervisorError,
                           match="after 2 recovery attempt"):
            sup.run_phase(run.runner, state, 0, max_steps=2, tag="phase1",
                          chunk_steps=1, chunk_filter=always_nan)
    assert sleeps == [0.5, 1.0]              # backoff_s * factor**(k-1)


def test_supervisor_rolls_back_transient_nan(tmp_path):
    """Acceptance (c) at the phase level: a one-shot NaN poisons the
    chunk ending at step 2; the supervisor rolls back to the verified
    step-1 snapshot, replays clean, and the phase completes — the
    poisoned state was never checkpointed."""
    run, state = _sgd_phase(max_steps=3)
    ckpt = Checkpointer(str(tmp_path), every=1)
    plan = FaultPlan().nan_at_step(2)
    sup = PhaseSupervisor(SupervisorConfig(max_retries=2),
                          sleep=lambda s: None)
    with pytest.warns(RuntimeWarning, match="divergence"):
        res = sup.run_phase(run.runner, state, 0, max_steps=3, tag="phase1",
                            chunk_steps=1, checkpointer=ckpt,
                            chunk_filter=plan.chunk_filter)
    assert state_step(res.state) == 3
    assert _params_finite(res.state)
    assert len(res.events) == 1
    ev = res.events[0]
    assert ev.kind == "divergence" and ev.restored_step == 1
    assert ev.restored_from.endswith("phase1-step00000001.msgpack")
    # every snapshot on disk is finite — the guard fired before the
    # checkpoint cadence could persist the poisoned chunk
    for c in list_checkpoints(str(tmp_path)):
        snap = load_train_state(c["path"], _sgd_phase()[1])
        assert _params_finite(snap), c["path"]


def test_supervisor_without_faults_is_transparent():
    run, state = _sgd_phase(max_steps=2)
    sup = PhaseSupervisor(SupervisorConfig(max_retries=1))
    res = sup.run_phase(run.runner, state, 0, max_steps=2, tag="phase1")
    assert state_step(res.state) == 2 and res.events == ()


# ---------------------------------------------------------------------------
# end-to-end chaos: supervised SWAP
# ---------------------------------------------------------------------------


def test_supervised_swap_survives_worker_death(tmp_path):
    """Acceptance (a): worker 3's heartbeat goes silent mid-phase-2. The
    supervisor drops it, resumes the surviving ensemble from the last
    verified snapshot, phase 3 averages only the survivors, and the
    averaged model beats the surviving-worker mean."""
    _, adapter, train, test_loader = _lm_setup()
    hb_dir = str(tmp_path / "hb")
    clock = FakeClock()
    plan = FaultPlan(clock).kill_worker(3, at_step=2)
    writers = [HeartbeatWriter(hb_dir, w, clock=clock) for w in range(4)]
    for w in writers:
        w.beat()
    monitor = HeartbeatMonitor(hb_dir, 4, timeout_s=2.5, clock=clock)
    sup = PhaseSupervisor(SupervisorConfig(max_retries=2), monitor=monitor,
                          sleep=lambda s: None)
    cfg = _swap_cfg(checkpoint_dir=str(tmp_path / "ckpts"),
                    checkpoint_every=1)
    dist = DistConfig(n_workers=4, elastic_deadline_s=30.0)
    swap = SWAP(adapter, cfg, train, test_loader, dist=dist, supervisor=sup)
    with pytest.warns(RuntimeWarning, match="worker_lost"):
        res = swap.run(jax.random.PRNGKey(0), collect_curves=True,
                       phase2_hooks=[plan.beat_hook(writers)],
                       heartbeats=monitor)

    assert res["phase2_worker_ids"] == [0, 1, 2]
    assert res["worker_live_mask"] == [True, True, True, False]
    assert res["phase2_live_workers"] == 3
    events = res["recovery_events"]
    assert len(events) == 1 and events[0]["kind"] == "worker_lost"
    assert events[0]["lost_workers"] == [3]
    assert events[0]["restored_from"].endswith(".msgpack")
    # the phase still reached its step target after the recovery replay
    assert res["phase2_steps"] == cfg.phase2.max_steps
    # the paper's claim survives the fault: averaging the surviving
    # ensemble is no worse than the mean surviving worker (same smoke-scale
    # tolerance as test_swap_integration — at a handful of SGD steps the
    # argmax-accuracy comparison carries ~1 token of sampling noise)
    assert res["after_avg_test_acc"] >= res["before_avg_test_acc"] - 0.01


def test_supervised_swap_recovers_from_nan_step(tmp_path):
    """Acceptance (c) end-to-end: a one-shot NaN in phase 2 rolls back to
    the last verified snapshot and the run completes with finite
    everything."""
    _, adapter, train, test_loader = _lm_setup()
    plan = FaultPlan().nan_at_step(2)
    sup = PhaseSupervisor(SupervisorConfig(max_retries=2),
                          sleep=lambda s: None)
    cfg = _swap_cfg(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    swap = SWAP(adapter, cfg, train, test_loader, supervisor=sup)
    with pytest.warns(RuntimeWarning, match="divergence"):
        res = swap.run(jax.random.PRNGKey(0), collect_curves=True,
                       phase2_chunk_filter=plan.chunk_filter)
    events = res["recovery_events"]
    assert len(events) == 1 and events[0]["kind"] == "divergence"
    assert res["phase2_steps"] == cfg.phase2.max_steps
    assert res["worker_live_mask"] == [True] * 4
    assert np.isfinite(res["after_avg_test_acc"])
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(
                   res["final_bundle"]["params"]))


def test_phase2_chunk_filter_requires_supervisor():
    _, adapter, train, test_loader = _lm_setup()
    swap = SWAP(adapter, _swap_cfg(), train, test_loader)
    with pytest.raises(ValueError, match="needs a supervisor"):
        swap.run(jax.random.PRNGKey(0),
                 phase2_chunk_filter=lambda s, m: (s, m))


def test_swap_resume_skips_corrupted_latest_checkpoint(tmp_path):
    """Acceptance (b): damage the newest snapshot after a run; a resumed
    run must fall back to the previous verified-good snapshot and still
    complete."""
    _, adapter, train, test_loader = _lm_setup()
    cfg = _swap_cfg(n_workers=2, checkpoint_dir=str(tmp_path),
                    checkpoint_every=1)
    SWAP(adapter, cfg, train, test_loader).run(jax.random.PRNGKey(0),
                                               collect_curves=True)
    victim = corrupt_latest_checkpoint(str(tmp_path), tag="phase2")
    good = find_resume_point(str(tmp_path))
    assert good is not None and good["path"] != victim
    with pytest.warns(RuntimeWarning, match="falling back"):
        res = SWAP(adapter, cfg, train, test_loader).run(
            jax.random.PRNGKey(1), resume=True)
    assert res["phase2_steps"] == cfg.phase2.max_steps
    assert 0.0 <= res["after_avg_test_acc"] <= 1.0


# ---------------------------------------------------------------------------
# publish delivery
# ---------------------------------------------------------------------------


def test_publisher_retries_through_injected_failures():
    plan = FaultPlan().fail_publishes(2)
    engine = plan.failing_engine()
    sleeps = []
    pub = WeightPublisher([engine], max_retries=2, retry_backoff_s=0.1,
                          sleep=sleeps.append)
    gen = pub.publish({"w": jnp.ones((2,), jnp.float32)}, step=7)
    assert gen == 1 and pub.generation == 1
    assert engine.delivered == [1]
    assert sleeps == pytest.approx([0.1, 0.2])   # exponential backoff
    assert pub.log == [{"generation": 1, "step": 7, "folds": 0}]


def test_publisher_skip_records_failure_and_recovers():
    """Acceptance (d): delivery fails past the retry budget; on_failure=
    'skip' records it, the generation counter never advances, and the
    NEXT publish lands as generation 1 — one lost delivery costs
    staleness, not the run."""
    plan = FaultPlan().fail_publishes(3)
    engine = plan.failing_engine()
    pub = WeightPublisher([engine], max_retries=1, retry_backoff_s=0.0,
                          on_failure="skip", sleep=lambda s: None)
    params = {"w": jnp.ones((2,), jnp.float32)}
    with pytest.warns(RuntimeWarning, match="skipping"):
        assert pub.publish(params, step=3) == 0
    assert pub.generation == 0 and pub.log == []
    assert len(pub.failures) == 1
    assert pub.failures[0]["step"] == 3
    assert pub.failures[0]["attempts"] == 2
    # the one remaining injected failure is absorbed by the next call's
    # retry budget: the publish lands under an un-burned generation number
    assert pub.publish(params, step=4) == 1
    assert engine.delivered == [1]


def test_publisher_raise_is_default_and_preserves_generation():
    plan = FaultPlan().fail_publishes(1)
    pub = WeightPublisher([plan.failing_engine()])
    with pytest.raises(RuntimeError, match="injected publish failure"):
        pub.publish({"w": jnp.ones((2,), jnp.float32)})
    assert pub.generation == 0 and pub.log == []


# ---------------------------------------------------------------------------
# serving degradation: bounded admission waits
# ---------------------------------------------------------------------------


def _serving_setup():
    from repro.models.model import Model
    cfg = registry.get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _req(cfg, rid, n_new=8, deadline_s=None, seed=1):
    from repro.serve.engine import Request
    prompt = jax.random.randint(jax.random.fold_in(
        jax.random.PRNGKey(seed), rid), (8,), 0, cfg.vocab_size,
        dtype=jnp.int32)
    return Request(rid=rid, prompt=prompt, max_new_tokens=n_new,
                   deadline_s=deadline_s)


def test_serving_rejects_request_past_admission_deadline():
    """Acceptance: a request that cannot be admitted before its deadline
    is REJECTED — done=True, rejected=True, counted — and the run loop
    terminates instead of hanging on it."""
    from repro.serve.compiled import CompiledServingEngine
    cfg, model, params = _serving_setup()
    clock = FakeClock()
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=64,
                                   decode_block=4, clock=clock)
    r1 = _req(cfg, 0, n_new=12)
    r2 = _req(cfg, 1, deadline_s=1.0)
    engine.submit(r1)                        # takes the only slot
    engine.submit(r2)                        # waits on it
    assert engine.waiting == [r2]
    clock.advance(2.0)                       # past r2's deadline
    steps = 0
    while (engine.active or engine.waiting) and steps < 50:
        engine.step()
        steps += 1
    assert steps < 50, "engine hung on an unadmittable request"
    assert r2.rejected and r2.done and r2.generated == []
    assert engine.stats["rejections"] == 1
    assert len(r1.generated) == 12           # the admitted request finished


def test_serving_engine_wide_admit_timeout():
    from repro.serve.compiled import CompiledServingEngine
    cfg, model, params = _serving_setup()
    clock = FakeClock()
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=64,
                                   decode_block=4, admit_timeout_s=3.0,
                                   clock=clock)
    r1 = _req(cfg, 0, n_new=12)
    r2 = _req(cfg, 1)                        # no per-request deadline:
    engine.submit(r1)                        # the engine-wide bound applies
    engine.submit(r2)
    clock.advance(10.0)
    engine.step()
    assert r2.rejected and engine.stats["rejections"] == 1


def test_serving_waits_within_deadline_then_admits():
    """A deadline that has NOT passed keeps legacy behavior: the request
    waits for a slot and completes normally once one frees."""
    from repro.serve.compiled import CompiledServingEngine
    cfg, model, params = _serving_setup()
    clock = FakeClock()
    engine = CompiledServingEngine(model, params, max_batch=1, max_seq=64,
                                   decode_block=4, clock=clock)
    r1 = _req(cfg, 0, n_new=4)
    r2 = _req(cfg, 1, n_new=4, deadline_s=100.0)
    engine.submit(r1)
    engine.submit(r2)
    steps = 0
    while (engine.active or engine.waiting) and steps < 50:
        engine.step()
        steps += 1
    assert not r2.rejected and len(r2.generated) == 4
    assert engine.stats["rejections"] == 0
