"""Live weight publishing: token-exactness under mid-decode hot swaps
(per-slot generation pinning across attention-KV, SSM, and sliding-window
caches), deferred-publish drain semantics, the WeightPublisher epoch hook
folding into a StreamingAverage, and PublishFollower poll semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.state import (find_latest_publish, list_publishes,
                                    load_publish)
from repro.configs import registry
from repro.core.averaging import average_stacked
from repro.launch.serve import generate
from repro.models.model import Model
from repro.serve.compiled import CompiledServingEngine
from repro.serve.engine import Request
from repro.serve.publish import PublishFollower, WeightPublisher
from repro.train.loop import init_train_state

_SETUP_CACHE = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = registry.get_smoke_config(arch)
        model = Model(cfg)
        p0 = model.init(jax.random.PRNGKey(0))
        p1 = model.init(jax.random.PRNGKey(1))
        p2 = model.init(jax.random.PRNGKey(2))
        _SETUP_CACHE[arch] = (cfg, model, (p0, p1, p2))
    return _SETUP_CACHE[arch]


def _prompt(cfg, length, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (length,), 0,
                              cfg.vocab_size, dtype=jnp.int32)


def _reference_tokens(model, params, prompt, n_new):
    out, _ = generate(model, params, prompt[None, :], n_new)
    return [int(t) for t in out[0]]


# the acceptance trio: attention-KV, SSM state, sliding-window cache —
# per-slot pinning must bitwise-select every cache layout correctly
@pytest.mark.parametrize("arch",
                         ["internlm2-1.8b", "mamba2-2.7b", "gemma3-1b"])
def test_token_exact_under_mid_decode_swap(arch):
    """A publish lands while request A is mid-decode; B is admitted after.
    A must finish token-exact on its admission weights (as if no publish
    ever happened) and B token-exact on the new generation — while the
    single-bulk-transfer-per-decode-call invariant holds."""
    cfg, model, (p0, p1, _) = _setup(arch)
    pa = _prompt(cfg, 9, seed=1)
    pb = _prompt(cfg, 7, seed=2)
    n_new = 12

    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64,
                                   decode_block=4)
    a = Request(rid=0, prompt=pa, max_new_tokens=n_new)
    b = Request(rid=1, prompt=pb, max_new_tokens=n_new)
    engine.submit(a)
    engine.step()                                # A is mid-decode (4 of 12)
    assert engine.publish(p1) is True            # inactive buffer is free
    assert engine.generation == 1
    engine.submit(b)                             # admitted at generation 1
    while engine.active or engine.waiting:
        engine.step()

    assert a.done and b.done
    assert (a.generation, b.generation) == (0, 1)
    assert a.generated == _reference_tokens(model, p0, pa, n_new), \
        "in-flight request's tokens changed under a mid-decode publish"
    assert b.generated == _reference_tokens(model, p1, pb, n_new), \
        "post-publish admission did not serve the new generation"
    st = engine.stats
    assert st["dual_decode_calls"] > 0, \
        "generations never overlapped — the swap was not mid-decode"
    assert st["decode_transfers"] == st["decode_calls"]
    assert st["publish_swaps"] == 1


def test_publish_deferred_until_pinned_buffer_drains():
    """Two live generations already occupy both buffers: a third publish
    must defer (never clobber weights a request still reads), then apply
    once the pinned generation drains; the next admission serves it."""
    cfg, model, (p0, p1, p2) = _setup("internlm2-1.8b")
    long_req = Request(rid=0, prompt=_prompt(cfg, 9, seed=1),
                       max_new_tokens=16)
    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64,
                                   decode_block=4)
    engine.submit(long_req)
    engine.step()                                 # pins buffer 0 (gen 0)
    assert engine.publish(p1) is True             # buffer 1 <- gen 1
    mid_req = Request(rid=1, prompt=_prompt(cfg, 7, seed=2),
                      max_new_tokens=4)
    engine.submit(mid_req)                        # pins buffer 1 (gen 1)

    assert engine.publish(p2) is False            # target = buffer 0: busy
    assert engine.generation == 1                 # still serving gen 1
    while not long_req.done:
        engine.step()
    # the drain freed buffer 0; the deferred generation must now be live
    assert engine.generation == 2
    late = Request(rid=2, prompt=_prompt(cfg, 5, seed=3), max_new_tokens=6)
    engine.submit(late)
    while engine.active or engine.waiting:
        engine.step()
    assert late.generation == 2
    assert late.generated == _reference_tokens(
        model, p2, late.prompt, 6)
    assert long_req.generated == _reference_tokens(
        model, p0, long_req.prompt, 16)
    assert engine.stats["publish_swaps"] == 2
    assert engine.stats["decode_transfers"] == engine.stats["decode_calls"]


def test_publish_superseded_and_stale():
    """Only the newest deferred publish survives; a stale generation
    number is rejected outright."""
    cfg, model, (p0, p1, p2) = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64,
                                   decode_block=4)
    req = Request(rid=0, prompt=_prompt(cfg, 9, seed=1), max_new_tokens=12)
    engine.submit(req)
    engine.step()                                 # pins buffer 0
    assert engine.publish(p1) is True             # gen 1 live in buffer 1
    assert engine.publish(p2) is False            # deferred (buffer 0 busy)
    assert engine.publish(p1, generation=1) is None    # stale: rejected
    p3 = jax.tree_util.tree_map(lambda x: x * 2, p2)
    assert engine.publish(p3) is False            # deferred, supersedes p2
    assert engine.stats["publish_superseded"] == 1
    while engine.active or engine.waiting:
        engine.step()
    engine._admit()                               # retry point for pending
    # generation numbering never reused: p2's queued gen 2 was discarded,
    # p3 took gen 3
    assert engine.generation == 3
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(engine.params)[0]),
        np.asarray(jax.tree_util.tree_leaves(p3)[0]))


def test_publish_shape_mismatch_raises():
    cfg, model, (p0, _, _) = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64)
    bad = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape + (1,),
                                                     x.dtype), p0)
    with pytest.raises(ValueError, match="different model config"):
        engine.publish(bad)


def test_weight_publisher_requires_sink():
    with pytest.raises(ValueError, match="somewhere to publish"):
        WeightPublisher()


def _stacked_state(trees, step):
    """Phase-2-shaped TrainState: leading worker axis on every leaf."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)
    W = len(trees)
    return init_train_state({"params": stacked, "state": {}},
                            opt_state={}, step=0)._replace(
        step=jnp.full((W,), step, jnp.int32))


def test_weight_publisher_epoch_hook_folds_running_average(tmp_path):
    """Two epoch boundaries: publish g is the streaming mean of the first
    g across-worker averages — Algorithm 1's phase-3 average computed
    online, one snapshot per epoch."""
    d = str(tmp_path)
    w = [{"k": jnp.full((3,), float(i), jnp.float32)} for i in range(4)]
    pub = WeightPublisher(directory=d, ensemble=True)

    pub.on_epoch(_stacked_state([w[0], w[1]], step=10), 10)
    pub.on_epoch(_stacked_state([w[2], w[3]], step=20), 20)

    pubs = list_publishes(d)
    assert [p["generation"] for p in pubs] == [1, 2]
    assert [p["step"] for p in pubs] == [10, 20]
    assert pubs[1]["meta"]["folds"] == 2
    g1 = load_publish(pubs[0]["path"], w[0])
    g2 = load_publish(pubs[1]["path"], w[0])
    # gen 1 = across-worker mean(w0, w1) = average_stacked of that epoch;
    # gen 2 = streaming mean of the two epoch means
    epoch1 = average_stacked(jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), w[0], w[1]))
    np.testing.assert_allclose(np.asarray(g1["k"]), np.asarray(epoch1["k"]))
    np.testing.assert_allclose(np.asarray(g1["k"]), 0.5)
    np.testing.assert_allclose(np.asarray(g2["k"]), (0.5 + 2.5) / 2)


def test_weight_publisher_every_skips_boundaries(tmp_path):
    d = str(tmp_path)
    w = {"k": jnp.ones((2,), jnp.float32)}
    pub = WeightPublisher(directory=d, ensemble=False, every=2)
    assert pub.on_epoch(init_train_state({"params": w, "state": {}},
                                         opt_state={}, step=5), 5) is None
    assert pub.on_epoch(init_train_state({"params": w, "state": {}},
                                         opt_state={}, step=9), 9) == 1
    assert len(list_publishes(d)) == 1


def test_publisher_rolls_back_generation_on_snapshot_failure(tmp_path):
    """Regression: publish() advanced self.generation and appended to the
    log even when save_publish raised — the durable record then lagged the
    counter forever. A failed snapshot must propagate WITHOUT consuming a
    generation number; the retry lands as the same generation."""
    import repro.serve.publish as publish_mod
    d = str(tmp_path)
    w = {"k": jnp.ones((2,), jnp.float32)}
    pub = WeightPublisher(directory=d, ensemble=False)

    real = publish_mod.save_publish
    publish_mod.save_publish = lambda *a, **k: (_ for _ in ()).throw(
        OSError("disk full"))
    try:
        with pytest.raises(OSError):
            pub.publish(w, step=5)
    finally:
        publish_mod.save_publish = real
    assert pub.generation == 0 and pub.log == []
    # the retry takes generation 1, not 2
    assert pub.publish(w, step=5) == 1
    assert [p["generation"] for p in list_publishes(d)] == [1]
    assert pub.log[-1]["generation"] == 1


def test_publisher_rolls_back_when_all_engines_reject_stale(tmp_path):
    """Regression: if every attached engine rejected the generation as
    stale (engine restarted ahead of the publisher, or two publishers
    race), the publisher still advanced its counter and logged a publish
    that never happened anywhere."""
    cfg, model, (p0, p1, p2) = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64)
    # engine is already serving generation 5 (restart / other publisher)
    assert engine.publish(p1, generation=5) is True
    pub = WeightPublisher([engine], ensemble=False)
    assert pub.generation == 0
    got = pub.publish(p2, step=9)          # queued as gen 1 -> stale
    assert got == 0                        # counter NOT advanced
    assert pub.log == []
    assert engine.generation == 5          # engine untouched


def test_publisher_engine_and_follower_roundtrip(tmp_path):
    """In-process engine swap and the cross-process follower observe the
    SAME generation: snapshot-first ordering means a follower can never be
    ahead of the durable record."""
    d = str(tmp_path)
    cfg, model, (p0, p1, _) = _setup("internlm2-1.8b")
    engine = CompiledServingEngine(model, p0, max_batch=2, max_seq=64)
    pub = WeightPublisher([engine], directory=d, ensemble=False)
    follower = PublishFollower(d, template=p0)
    assert follower.poll() is None               # nothing published yet

    gen = pub.publish(p1, step=17)
    assert gen == 1 and engine.generation == 1
    polled = follower.poll()
    assert polled is not None
    got_gen, got_params = polled
    assert got_gen == 1
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(got_params)[0]),
        np.asarray(jax.tree_util.tree_leaves(p1)[0]))
    assert follower.poll() is None               # already consumed
    latest = find_latest_publish(d)
    assert latest["generation"] == 1 and latest["step"] == 17
