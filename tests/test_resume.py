"""Checkpoint/resume: interrupting a SWAP run mid-phase-1 or mid-phase-2
and resuming must reproduce the uninterrupted run bitwise — identical final
parameters AND identical metric logs for the post-resume steps.

The interruption is simulated faithfully: run an uninterrupted job with
periodic snapshots, then copy its checkpoint directory and DELETE every
snapshot written after the interruption point — exactly the on-disk state a
killed process would leave — and launch a fresh SWAP with ``resume=True``.
"""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.state import (Checkpointer, find_resume_point,
                                    load_train_state, save_train_state)
from repro.configs.base import (ModelConfig, OptimizerConfig, PhaseConfig,
                                ScheduleConfig, SWAPConfig)
from repro.core.adapters import LMAdapter
from repro.core.swap import SWAP
from repro.data.pipeline import Loader, make_markov_lm
from repro.train.loop import init_train_state


def tiny_lm() -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=32, attention="gqa",
        dtype="float32", remat=False, scan_layers=False)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def task():
    cfg = tiny_lm()
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=128, n_test=64,
                          seq_len=16)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    test_loader = Loader({"tokens": data["test_tokens"],
                          "labels": data["test_labels"]}, 64)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    return adapter, train, test_loader


def _swap_cfg(ckpt_dir: str) -> SWAPConfig:
    # phase 1: batch 32 over 128 samples -> spe 4, 8 steps = chunks [4, 4],
    #   snapshots at steps 4 and 8 (checkpoint_every=4)
    # phase 2: batch 32 -> spe 4, 6 steps = chunks [4, 2], snapshot at 4
    return SWAPConfig(
        n_workers=2,
        phase1=PhaseConfig(batch_size=32, max_steps=8,
                           schedule=ScheduleConfig(kind="const", peak_lr=0.1)),
        phase2=PhaseConfig(batch_size=32, max_steps=6,
                           schedule=ScheduleConfig(kind="const",
                                                   peak_lr=0.05)),
        bn_recompute_batch_size=64, bn_recompute_batches=2, seed=0,
        checkpoint_dir=ckpt_dir, checkpoint_every=4)


@pytest.fixture(scope="module")
def uninterrupted(task, tmp_path_factory):
    adapter, train, test_loader = task
    ckpt_dir = str(tmp_path_factory.mktemp("ckpts") / "run")
    res = SWAP(adapter, _swap_cfg(ckpt_dir), train, test_loader).run(
        jax.random.PRNGKey(0))
    return ckpt_dir, res


def _interrupt_dir(src: str, dst: str, keep) -> str:
    """Copy a checkpoint dir, keeping only snapshots written before the
    simulated kill (``keep(filename) -> bool``)."""
    shutil.copytree(src, dst)
    for name in os.listdir(dst):
        if not keep(name):
            os.remove(os.path.join(dst, name))
    return dst


def test_uninterrupted_run_writes_expected_snapshots(uninterrupted):
    ckpt_dir, _ = uninterrupted
    names = sorted(os.listdir(ckpt_dir))
    assert "phase1-step00000004.msgpack" in names
    assert "phase1-step00000008.msgpack" in names
    assert "phase1_final-step00000008.msgpack" in names
    assert "phase2-step00000004.msgpack" in names


def test_resume_mid_phase1_is_bitwise_identical(task, uninterrupted,
                                                tmp_path):
    adapter, train, test_loader = task
    src, res_a = uninterrupted
    dst = _interrupt_dir(src, str(tmp_path / "mid_p1"),
                         keep=lambda n: n.startswith("phase1-step00000004"))
    res_b = SWAP(adapter, _swap_cfg(dst), train, test_loader).run(
        jax.random.PRNGKey(0), resume=True)

    _assert_trees_equal(res_a["final_bundle"]["params"],
                        res_b["final_bundle"]["params"])
    _assert_trees_equal(res_a["stacked_params"], res_b["stacked_params"])
    # the resumed process re-executes steps 4..7; its metric log must match
    # the tail of the uninterrupted log bitwise
    tail_a = [e for e in res_a["phase1_log"] if e["step"] >= 4]
    assert res_b["phase1_log"] == tail_a
    assert res_b["phase1_steps"] == res_a["phase1_steps"]
    assert res_b["after_avg_test_acc"] == res_a["after_avg_test_acc"]


def test_resume_mid_phase2_is_bitwise_identical(task, uninterrupted,
                                                tmp_path):
    adapter, train, test_loader = task
    src, res_a = uninterrupted
    dst = _interrupt_dir(
        src, str(tmp_path / "mid_p2"),
        keep=lambda n: (n.startswith("phase1-")
                        or n.startswith("phase1_final-")
                        or n.startswith("phase2-step00000004")))
    res_b = SWAP(adapter, _swap_cfg(dst), train, test_loader).run(
        jax.random.PRNGKey(0), resume=True)

    _assert_trees_equal(res_a["final_bundle"]["params"],
                        res_b["final_bundle"]["params"])
    _assert_trees_equal(res_a["stacked_params"], res_b["stacked_params"])
    # phase 1 was not re-run: its summary metrics come from phase1_final
    assert res_b["phase1_log"] == []
    assert res_b["phase1_steps"] == res_a["phase1_steps"]
    assert res_b["phase1_test_acc"] == res_a["phase1_test_acc"]
    assert res_b["worker_test_accs"] == res_a["worker_test_accs"]
    assert res_b["after_avg_test_acc"] == res_a["after_avg_test_acc"]


def test_resume_phase2_with_fewer_workers(task, uninterrupted, tmp_path):
    """Worker-count-aware resume: a 2-worker phase-2 checkpoint resumed by
    a 1-worker run keeps worker 0's trajectory (the dropped tail is
    discarded), and the final average folds only the surviving worker.

    Tolerances, not bitwise: the W=1 and W=2 ensembles are separate XLA
    compilations whose fusion differs, so the shared trajectory agrees to
    f32 ulps rather than exactly (same-W resume IS bitwise — asserted
    above)."""
    adapter, train, test_loader = task
    src, res_a = uninterrupted
    dst = _interrupt_dir(
        src, str(tmp_path / "shrink"),
        keep=lambda n: (n.startswith("phase1-")
                        or n.startswith("phase1_final-")
                        or n.startswith("phase2-step00000004")))
    cfg = dataclasses.replace(_swap_cfg(dst), n_workers=1)
    res_b = SWAP(adapter, cfg, train, test_loader).run(
        jax.random.PRNGKey(0), resume=True)

    surviving = jax.tree_util.tree_map(lambda a: a[:1],
                                       res_a["stacked_params"])
    for a, b in zip(jax.tree_util.tree_leaves(surviving),
                    jax.tree_util.tree_leaves(res_b["stacked_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(res_b["worker_test_accs"],
                               res_a["worker_test_accs"][:1], atol=1e-3)


def test_resume_phase2_with_more_workers_refused(task, uninterrupted,
                                                 tmp_path):
    """Growing the ensemble on resume is refused: cloned workers would
    share a trajectory, breaking the independence the average relies on."""
    adapter, train, test_loader = task
    src, _ = uninterrupted
    dst = _interrupt_dir(
        src, str(tmp_path / "grow"),
        keep=lambda n: (n.startswith("phase1-")
                        or n.startswith("phase1_final-")
                        or n.startswith("phase2-step00000004")))
    cfg = dataclasses.replace(_swap_cfg(dst), n_workers=3)
    with pytest.raises(ValueError, match="cloned workers"):
        SWAP(adapter, cfg, train, test_loader).run(
            jax.random.PRNGKey(0), resume=True)


# ---------------------------------------------------------------------------
# checkpoint-layer units (no training)
# ---------------------------------------------------------------------------


def test_shrink_worker_axis_units():
    from repro.checkpoint.state import checkpoint_workers, shrink_worker_axis
    from repro.train.loop import stack_train_state

    assert checkpoint_workers({"n_workers": 4}) == 4
    assert checkpoint_workers({}) is None          # pre-elastic sidecar

    bundle = {"params": {"w": jnp.arange(6.0).reshape(3, 2)}, "state": {}}
    opt = {"mu": {"w": jnp.zeros((3, 2))}}
    state = stack_train_state(bundle, opt, 3)
    assert shrink_worker_axis(state, 3) is state   # no-op keeps buffers

    small = shrink_worker_axis(state, 2)
    _assert_trees_equal(
        small, jax.tree_util.tree_map(lambda a: a[:2], state))

    with pytest.raises(ValueError, match="cloned workers"):
        shrink_worker_axis(state, 4)


def test_train_state_roundtrip_is_byte_exact(tmp_path):
    bundle = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                         "b": jnp.ones((4,), jnp.bfloat16)},
              "state": {}}
    opt = {"mu": jax.tree_util.tree_map(jnp.zeros_like, bundle["params"])}
    state = init_train_state(bundle, opt, step=17, acc_ema=0.25)
    path = str(tmp_path / "st.msgpack")
    save_train_state(path, state, meta={"tag": "phase1", "step": 17})
    out = load_train_state(path, state)
    _assert_trees_equal(state, out)
    assert int(np.asarray(out.step)) == 17


def test_checkpointer_cadence_and_resume_priority(tmp_path):
    bundle = {"params": {"w": jnp.zeros((2, 2))}, "state": {}}
    opt = {"mu": {"w": jnp.zeros((2, 2))}}

    def at(step):
        return init_train_state(bundle, opt, step=step)

    ck = Checkpointer(str(tmp_path), every=4, keep=2)
    assert ck.maybe_save("phase1", at(2)) is None      # off-cadence
    assert ck.maybe_save("phase1", at(4)) is not None
    assert ck.maybe_save("phase1", at(4)) is None      # no duplicate
    assert ck.maybe_save("phase1", at(8)) is not None
    assert ck.maybe_save("phase1", at(12)) is not None
    # keep=2 pruned the oldest rolling snapshot
    names = [n for n in os.listdir(tmp_path) if n.endswith(".msgpack")]
    assert sorted(names) == ["phase1-step00000008.msgpack",
                             "phase1-step00000012.msgpack"]

    ck.save("phase1_final", at(12))
    assert find_resume_point(str(tmp_path))["tag"] == "phase1_final"
    ck.maybe_save("phase2", at(4))
    pt = find_resume_point(str(tmp_path))
    assert (pt["tag"], pt["step"]) == ("phase2", 4)
    assert pt["meta"]["tag"] == "phase2"

    assert find_resume_point(str(tmp_path / "missing")) is None


def test_checkpointer_resume_seeds_cadence_from_disk(tmp_path):
    """Regression: a FRESH Checkpointer over an existing directory started
    with an empty _last_saved map, so a resumed run re-snapshotted at its
    very first epoch boundary regardless of the `every` cadence. The
    cadence must seed from the snapshots already on disk, per tag."""
    bundle = {"params": {"w": jnp.zeros((2, 2))}, "state": {}}
    opt = {"mu": {"w": jnp.zeros((2, 2))}}

    def at(step):
        return init_train_state(bundle, opt, step=step)

    ck = Checkpointer(str(tmp_path), every=4, keep=2)
    assert ck.maybe_save("phase1", at(8)) is not None
    assert ck.maybe_save("phase2", at(6)) is not None

    resumed = Checkpointer(str(tmp_path), every=4, keep=2)
    # step 10 is only 2 past phase1's durable step 8: off-cadence
    assert resumed.maybe_save("phase1", at(10)) is None
    # per-tag seeding: phase2 last saved at 6, so 10 is due
    assert resumed.maybe_save("phase2", at(10)) is not None
    assert resumed.maybe_save("phase1", at(12)) is not None
