"""int8 KV cache: quantization round-trip + end-to-end decode fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import replace
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.model import Model


def test_quant_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 2, 32))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = dequantize_kv(q, s, jnp.float32)
    # symmetric int8: max error <= scale/2 = amax/254 per row
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert (np.abs(np.asarray(back) - np.asarray(x))
            <= amax / 254 + 1e-6).all()


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-1b",
                                  "whisper-base"])
def test_int8_cache_decode_close_to_full_precision(arch):
    cfg = replace(registry.get_smoke_config(arch), kv_cache_dtype="int8")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S, T = 2, 24, 3
    tokens = jax.random.randint(key, (B, S + T), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    logits_full, _ = model.apply(params, tokens, **extras)
    lp, cache = model.prefill(params, tokens[:, :S], cache_len=S + T,
                              **extras)
    # cache really is int8
    assert any("k_scale" in str(p) for p, _ in
               jax.tree_util.tree_flatten_with_path(cache)[0])
    for t in range(T):
        ld, cache = model.decode(params, cache, tokens[:, S + t][:, None],
                                 S + t)
        # quantization noise bounded; greedy argmax should agree
        np.testing.assert_allclose(np.asarray(ld),
                                   np.asarray(logits_full[:, S + t]),
                                   atol=0.08, rtol=0.1)
        assert (np.argmax(np.asarray(ld), -1)
                == np.argmax(np.asarray(logits_full[:, S + t]), -1)).all()
