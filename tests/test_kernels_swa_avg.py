"""swa_avg kernel: streaming average == arithmetic mean, across impls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.swa_avg.ops import running_average, running_average_tree


@pytest.mark.parametrize("impl", ["reference", "pallas"])
@pytest.mark.parametrize("shape", [(17,), (1000, 37), (3, 5, 7), (8192,)])
def test_running_average_matches_mean(impl, shape):
    ws = [jax.random.normal(jax.random.PRNGKey(i), shape) for i in range(5)]
    avg = ws[0]
    for n, w in enumerate(ws[1:], start=1):
        avg = running_average(avg, w, float(n), impl=impl)
    want = jnp.mean(jnp.stack(ws), axis=0)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_tree_form(impl):
    t1 = {"a": jnp.ones((10, 3)), "b": {"c": jnp.zeros((7,))}}
    t2 = {"a": 3 * jnp.ones((10, 3)), "b": {"c": 2 * jnp.ones((7,))}}
    avg = running_average_tree(t1, t2, 1.0, impl=impl)
    np.testing.assert_allclose(np.asarray(avg["a"]), 2.0)
    np.testing.assert_allclose(np.asarray(avg["b"]["c"]), 1.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 50), size=st.integers(1, 300))
def test_property_streaming_equals_mean(n, size):
    """Property: folding k models one at a time equals their mean,
    regardless of k and buffer size (incl. non-tile-aligned sizes)."""
    ws = [jax.random.normal(jax.random.PRNGKey(i), (size,))
          for i in range(min(n, 6))]
    avg = ws[0]
    for i, w in enumerate(ws[1:], start=1):
        avg = running_average(avg, w, float(i), impl="pallas")
    want = jnp.mean(jnp.stack(ws), axis=0)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(want), atol=1e-5,
                               rtol=1e-5)
