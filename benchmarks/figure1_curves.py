"""Figure 1 analog: per-worker test accuracy vs the averaged model during
phase 2 — the averaged model should sit ABOVE every worker curve."""
from __future__ import annotations

import json

from benchmarks.common import cnn_task, run_swap

SWAP_HP = dict(workers=4, b1=512, b2=64, steps1=120, steps2=48,
               lr1=1.2, lr2=0.15, stop_acc=0.93)


def run(verbose=True):
    adapter, train, test_loader = cnn_task(seed=0, noise=3.5)
    swap = run_swap(adapter, train, test_loader, seed=0,
                    collect_curves=True, **SWAP_HP)
    curves = swap["phase2_curves"]
    n_above = sum(c["avg_test_acc"] >= max(c["worker_test_accs"]) - 1e-9
                  for c in curves[len(curves) // 2:])
    if verbose:
        print("\n== Figure 1 analog (phase-2 curves) ==")
        print("step, worker_accs..., avg_acc")
        for c in curves:
            ws = " ".join(f"{a:.3f}" for a in c["worker_test_accs"])
            print(f"{c['step']:4d}  [{ws}]  avg={c['avg_test_acc']:.3f}")
        print(f"averaged model >= best worker in {n_above}/"
              f"{len(curves) - len(curves) // 2} late-phase steps")
    return {"curves": curves, "late_steps_avg_above_best": n_above}


def main():
    out = run()
    with open("results/figure1.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
