"""Enforce benchmark floors + print the perf trajectory (CI bench job).

Compares a freshly produced benchmark JSON against the checked-in
``BENCH_*.json`` baseline. The contract is the ``tracked`` section both
files carry — ``{metric: {"value": v, "floor": f, "stable": bool?}}``,
higher is better:

  * every tracked metric must land at or above the BASELINE's floor (the
    checked-in floor is the repo's promise; a fresh run can't weaken it);
  * metrics marked ``"stable": true`` (deterministic facts like compiled
    peak-memory reductions) must additionally not FALL more than
    ``--tolerance`` (default 20%) below the checked-in value — a drop
    there is a real regression, not runner noise. Upward drift past the
    same tolerance doesn't fail (it may be a genuine improvement) but is
    flagged in the table as a stale baseline to refresh. Timing ratios
    are left un-pinned to the baseline because shared CI runners wobble;
    their floors still bind.

Prints a trajectory table (baseline -> fresh, delta) and appends it as
markdown to ``$GITHUB_STEP_SUMMARY`` when set.

Two invocation modes:

  # one explicit pair
  python benchmarks/check_regression.py \
      --baseline BENCH_precision.json --fresh /tmp/bench_precision.json

  # glob discovery: every checked-in BENCH_*.json is a contract; each
  # must have a fresh counterpart bench_*.json in --fresh-dir. A NEW
  # benchmark is enforced the moment its baseline lands — no CI edits.
  python benchmarks/check_regression.py --fresh-dir /tmp
"""
from __future__ import annotations

import argparse
import glob as globlib
import json
import os
import sys


def load_tracked(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    tracked = data.get("tracked")
    if not tracked:
        raise SystemExit(f"{path} has no 'tracked' section — regenerate it "
                         f"with the current benchmark script")
    return tracked


def check(baseline: dict, fresh: dict, tolerance: float):
    """Returns (rows, failures). Each row: (metric, base, new, min_allowed,
    ok)."""
    rows, failures = [], []
    for name, b in sorted(baseline.items()):
        if name not in fresh:
            failures.append(f"tracked metric {name!r} missing from fresh "
                            f"run — did the benchmark change shape?")
            continue
        new = float(fresh[name]["value"])
        base = float(b["value"])
        min_allowed = float(b.get("floor", 0.0))
        if b.get("stable"):
            min_allowed = max(min_allowed, base * (1.0 - tolerance))
        ok = new >= min_allowed
        if not ok:
            failures.append(
                f"{name}: {new} below minimum {min_allowed:.3f} "
                f"(baseline {base}, floor {b.get('floor')})")
        stale = (b.get("stable") and base
                 and new > base * (1.0 + tolerance))
        rows.append((name + (" (refresh baseline?)" if stale else ""),
                     base, new, min_allowed, ok))
    for name in sorted(set(fresh) - set(baseline)):
        rows.append((f"{name} (new)", float("nan"),
                     float(fresh[name]["value"]),
                     float(fresh[name].get("floor", 0.0)), True))
    return rows, failures


def render(rows, title: str) -> str:
    lines = [f"### {title}", "",
             "| metric | baseline | fresh | min allowed | Δ vs baseline | |",
             "|---|---|---|---|---|---|"]
    for name, base, new, min_allowed, ok in rows:
        delta = "" if base != base else f"{(new - base) / base:+.1%}"
        mark = "✅" if ok else "❌"
        base_s = "—" if base != base else f"{base}"
        lines.append(f"| {name} | {base_s} | {new} | {min_allowed:.3f} "
                     f"| {delta} | {mark} |")
    return "\n".join(lines) + "\n"


def fresh_name(baseline_path: str) -> str:
    """BENCH_train_loop.json -> bench_train_loop.json (the name every
    benchmark script writes with --out)."""
    base = os.path.basename(baseline_path)
    return base.replace("BENCH_", "bench_", 1)


def discover_pairs(baseline_glob: str, fresh_dir: str):
    """(baseline, fresh) pairs from the checked-in BENCH_*.json set. A
    baseline without a fresh counterpart is reported as (baseline, None)
    so a benchmark that silently stopped running fails the job."""
    baselines = sorted(globlib.glob(baseline_glob))
    if not baselines:
        raise SystemExit(f"no baselines match {baseline_glob!r}")
    return [(b, os.path.join(fresh_dir, fresh_name(b))) for b in baselines]


def check_pair(baseline: str, fresh: str, tolerance: float):
    """Returns (table-markdown, failures) for one baseline/fresh pair."""
    if not os.path.exists(fresh):
        return "", [f"{os.path.basename(baseline)}: fresh result "
                    f"{fresh} missing — did CI run this benchmark?"]
    rows, failures = check(load_tracked(baseline), load_tracked(fresh),
                           tolerance)
    table = render(rows, f"Perf trajectory: {os.path.basename(baseline)}")
    return table, [f"{os.path.basename(baseline)}: {m}" for m in failures]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", help="checked-in BENCH_*.json "
                    "(single-pair mode; requires --fresh)")
    ap.add_argument("--fresh", help="result JSON from this run")
    ap.add_argument("--baseline-glob", default="BENCH_*.json",
                    help="glob of checked-in baselines (discovery mode)")
    ap.add_argument("--fresh-dir",
                    help="directory holding fresh bench_*.json results; "
                         "enables discovery mode over --baseline-glob")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop vs baseline for metrics "
                         "marked stable (default 0.2)")
    args = ap.parse_args()

    if bool(args.baseline) == bool(args.fresh_dir):
        raise SystemExit("pass either --baseline/--fresh (one pair) or "
                         "--fresh-dir (glob discovery), not both/neither")
    if args.baseline:
        if not args.fresh:
            raise SystemExit("--baseline requires --fresh")
        pairs = [(args.baseline, args.fresh)]
    else:
        pairs = discover_pairs(args.baseline_glob, args.fresh_dir)

    all_failures, n_checked = [], 0
    for baseline, fresh in pairs:
        table, failures = check_pair(baseline, fresh, args.tolerance)
        all_failures.extend(failures)
        if table:
            n_checked += 1
            print(table)
            summary = os.environ.get("GITHUB_STEP_SUMMARY")
            if summary:
                with open(summary, "a") as f:
                    f.write(table + "\n")

    if all_failures:
        for msg in all_failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print(f"{n_checked} baseline(s) checked, all tracked metrics within "
          f"bounds")


if __name__ == "__main__":
    main()
