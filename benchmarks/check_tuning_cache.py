"""Schema-check the persisted kernel tuning cache (CI lint job).

Loads ``src/repro/kernels/tuning.py`` directly by file path — NOT via the
``repro.kernels`` package, whose ``__init__`` imports JAX — so this check
runs on the lint host, which installs only ruff. Validates that every
entry in ``tuning_cache.json`` parses and its key matches the
``backend/kernel/bucket`` format (tuning.validate_cache).
"""
from __future__ import annotations

import importlib.util
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TUNING_PY = os.path.join(REPO, "src", "repro", "kernels", "tuning.py")


def load_tuning_module():
    spec = importlib.util.spec_from_file_location("_repro_tuning", TUNING_PY)
    mod = importlib.util.module_from_spec(spec)
    # dataclasses resolves annotations via sys.modules[cls.__module__]
    sys.modules["_repro_tuning"] = mod
    spec.loader.exec_module(mod)
    return mod


def main() -> int:
    tuning = load_tuning_module()
    path = tuning.CACHE_PATH
    if not os.path.exists(path):
        print(f"FAIL: tuning cache missing at {path} — regenerate with "
              f"benchmarks/bench_kernels.py --update-cache", file=sys.stderr)
        return 1
    with open(path) as f:
        try:
            data = json.load(f)
        except json.JSONDecodeError as e:
            print(f"FAIL: {path} is not valid JSON: {e}", file=sys.stderr)
            return 1
    errs = tuning.validate_cache(data)
    if errs:
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    n = len(data.get("entries", {}))
    print(f"tuning cache OK: {n} entries at {os.path.relpath(path, REPO)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
