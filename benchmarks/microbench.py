"""Kernel microbenchmarks (CPU wall-clock; the TPU story is the dry-run).
Emits ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.swa_avg.ops import running_average


def _time(fn, *args, iters=5):
    return time_kernel(fn, *args, iters=iters) * 1e6


def run(verbose=True):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: B=2, S=512, H=8, D=64, GQA 2
    q = jax.random.normal(key, (2, 512, 8, 64))
    k = jax.random.normal(key, (2, 512, 2, 64))
    v = jax.random.normal(key, (2, 512, 2, 64))
    for impl in ("naive", "reference", "pallas"):
        fn = jax.jit(lambda q, k, v, impl=impl: flash_attention(
            q, k, v, impl=impl, chunk=128))
        us = _time(fn, q, k, v)
        flops = 2 * 2 * 512 * 512 * 8 * 64 * 2
        rows.append(csv_row(f"flash_attention[{impl}]", us,
                            f"{flops/us/1e3:.1f}GFLOP/s"))

    # ssd: B=2, S=512, H=8, P=32, N=16
    x = jax.random.normal(key, (2, 512, 8, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (2, 512, 8)))
    A = -jnp.exp(jax.random.normal(key, (8,)))
    Bm = jax.random.normal(key, (2, 512, 1, 16))
    Cm = jax.random.normal(key, (2, 512, 1, 16))
    D = jax.random.normal(key, (8,))
    for impl in ("naive", "reference", "pallas"):
        fn = jax.jit(lambda *a, impl=impl: ssd_scan(*a, impl=impl,
                                                    chunk=128)[0])
        us = _time(fn, x, dt, A, Bm, Cm, D)
        rows.append(csv_row(f"ssd_scan[{impl}]", us,
                            f"S=512 chunk=128"))

    # swa_avg: 10M-element buffer
    w1 = jax.random.normal(key, (10_000_000,))
    w2 = jax.random.normal(jax.random.PRNGKey(1), (10_000_000,))
    for impl in ("reference", "pallas"):
        fn = jax.jit(lambda a, b, impl=impl: running_average(a, b, 3.0,
                                                             impl=impl))
        us = _time(fn, w1, w2)
        gb = 3 * 4 * 10e6 / 2**30
        rows.append(csv_row(f"swa_avg[{impl}]", us,
                            f"{gb/(us/1e6):.1f}GiB/s"))
    if verbose:
        print("\n== kernel microbench (CPU; interpret-mode pallas) ==")
        for r in rows:
            print(r)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
