"""Beyond-paper ablation: SWAP quality vs worker count W (the paper fixes
W=8 for CIFAR and W=2 for ImageNet; here we sweep W at a fixed total
phase-2 sample budget to see where the averaging benefit saturates)."""
from __future__ import annotations

import json

from benchmarks.common import cnn_task, mean_std, run_swap

BASE = dict(b1=512, b2=64, steps1=120, steps2=96, lr1=1.2, lr2=0.15,
            stop_acc=0.93)


def run(seeds=(0, 1), verbose=True):
    rows = {}
    for W in (1, 2, 4, 8):
        accs_b, accs_a = [], []
        for seed in seeds:
            adapter, train, test_loader = cnn_task(seed=seed, noise=3.5)
            s = run_swap(adapter, train, test_loader, workers=W, seed=seed,
                         **BASE)
            accs_b.append(s["before_avg_test_acc"])
            accs_a.append(s["after_avg_test_acc"])
        rows[W] = {"before": accs_b, "after": accs_a}
    if verbose:
        print("\n== Ablation: SWAP vs worker count ==")
        print(f"{'W':>3s} {'before avg':>18s} {'after avg':>18s} {'gain':>8s}")
        for W, v in rows.items():
            gain = (sum(v["after"]) - sum(v["before"])) / len(v["after"])
            print(f"{W:3d} {mean_std(v['before']):>18s} "
                  f"{mean_std(v['after']):>18s} {gain:+8.4f}")
    return rows


def main():
    out = run()
    with open("results/ablation_workers.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
