"""Figures 2/3 analog: train/test error on the 2-D plane spanned by the
phase-1 output ('LB'), one phase-2 worker ('SGD'), and the averaged model
('SWAP'). The paper's observation: LB and the workers sit on the EDGES of an
almost-convex train-loss basin; SWAP sits nearer the center and wins on test
error. We emit the error grid as JSON (plane coordinates + errors) and check
the centrality claim numerically."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_task, run_swap
from repro.core.averaging import average_stacked
from repro.data.pipeline import Loader

SWAP_HP = dict(workers=4, b1=512, b2=64, steps1=120, steps2=64,
               lr1=1.2, lr2=0.15, stop_acc=0.93)
GRID = 9


def _flat(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1) for l in leaves]), \
        [l.shape for l in leaves]


def _unflat(vec, template):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(vec[off:off + n].reshape(l.shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def run(verbose=True):
    adapter, train, test_loader = cnn_task(seed=0, noise=3.5)
    train_loader = Loader(train, 256)
    swap = run_swap(adapter, train, test_loader, seed=0, **SWAP_HP)

    theta_lb, _ = _flat(swap["phase1_bundle"]["params"])
    theta_sgd, _ = _flat(jax.tree_util.tree_map(
        lambda a: a[0], swap["stacked_params"]))
    theta_swap, _ = _flat(average_stacked(swap["stacked_params"]))

    # orthonormal plane basis through the three points (Garipov-style)
    u = theta_sgd - theta_lb
    v = theta_swap - theta_lb
    v = v - u * (jnp.vdot(u, v) / jnp.vdot(u, u))
    unorm, vnorm = jnp.linalg.norm(u), jnp.linalg.norm(v)
    uhat, vhat = u / unorm, v / vnorm

    def coords(theta):
        d = theta - theta_lb
        return float(jnp.vdot(d, uhat)), float(jnp.vdot(d, vhat))

    pts = {"LB": coords(theta_lb), "SGD": coords(theta_sgd),
           "SWAP": coords(theta_swap)}

    # evaluate error over the bounding grid (with margin), recomputing BN
    # stats per plane point exactly as the paper does
    all_a = [p[0] for p in pts.values()]
    all_b = [p[1] for p in pts.values()]
    amin, amax = min(all_a), max(all_a)
    bmin, bmax = min(all_b), max(all_b)
    ma, mb = 0.4 * (amax - amin + 1e-9), 0.4 * (bmax - bmin + 1e-9)
    alphas = np.linspace(amin - ma, amax + ma, GRID)
    betas = np.linspace(bmin - mb, bmax + mb, GRID)

    template = swap["phase1_bundle"]["params"]
    grid = []
    for a in alphas:
        for b in betas:
            theta = theta_lb + a * uhat + b * vhat
            params = _unflat(theta, template)
            bundle = adapter.finalize(params, train_loader, n_batches=2)
            tr = adapter.eval_accuracy(bundle, Loader(train, 256),
                                       max_batches=2)
            te = adapter.eval_accuracy(bundle, test_loader, max_batches=2)
            grid.append({"alpha": float(a), "beta": float(b),
                         "train_err": 1 - tr, "test_err": 1 - te})

    # errors AT the exact three points (grid cells are too coarse to
    # separate them), BN stats recomputed per point as the paper does
    exact = {}
    for name, theta in (("LB", theta_lb), ("SGD", theta_sgd),
                        ("SWAP", theta_swap)):
        bundle = adapter.finalize(_unflat(theta, template), train_loader,
                                  n_batches=4)
        exact[name] = {
            "train_err": 1 - adapter.eval_accuracy(bundle, Loader(train, 256),
                                                   max_batches=4),
            "test_err": 1 - adapter.eval_accuracy(bundle, test_loader,
                                                  max_batches=4)}

    result = {"points": pts, "grid": grid,
              "train_err": {k: exact[k]["train_err"] for k in exact},
              "test_err": {k: exact[k]["test_err"] for k in exact}}
    if verbose:
        print("\n== Figure 2/3 analog (loss-landscape plane) ==")
        print("points (plane coords):", {k: tuple(round(x, 2) for x in v)
                                         for k, v in pts.items()})
        print("nearest-grid train err:", {k: round(v, 3) for k, v
                                          in result["train_err"].items()})
        print("nearest-grid test err: ", {k: round(v, 3) for k, v
                                          in result["test_err"].items()})
    return result


def main():
    out = run()
    with open("results/figure23.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
