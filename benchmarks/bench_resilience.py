"""Resilience overhead + recovery drill: what supervision costs when
nothing fails, and that a mid-phase-2 worker death actually recovers.

Two measurements:

  * **supervised zero-fault overhead** — the SAME compiled phase run bare
    (``run_phase``) and under a ``PhaseSupervisor`` with no faults
    injected. The supervisor's per-chunk health guard (host loss/EMA
    checks + one jitted all-finite params reduction) is the entire
    steady-state price of fault tolerance; the tracked floor says it may
    cost at most ~40% of hot-path throughput (in practice the guard is a
    single scalar transfer per chunk and the ratio sits near 1.0).
  * **death recovery drill** — the chaos scenario from
    ``tests/test_resilience.py`` timed end-to-end: a 4-worker supervised
    SWAP run where worker 3's heartbeat goes silent mid-phase-2. Tracked
    is the binary outcome (the run completed, the survivors finished the
    phase, exactly one recovery event) — a perf-floor on wall time would
    wobble with runner noise, so time-to-recover is reported but not
    enforced.

  PYTHONPATH=src python benchmarks/bench_resilience.py --smoke \
      [--out BENCH_resilience.json]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
import warnings

import jax
from common import lm_task

from repro.configs.base import PhaseConfig, ScheduleConfig, SWAPConfig
from repro.core.swap import SGDRun, SWAP
from repro.dist.config import DistConfig
from repro.dist.heartbeat import HeartbeatMonitor, HeartbeatWriter
from repro.resilience import PhaseSupervisor, SupervisorConfig
from repro.testing.faults import FakeClock, FaultPlan
from repro.train.loop import run_phase


def bench_overhead(smoke: bool):
    """(bare_train_s, supervised_train_s, steps) on an identical phase."""
    steps = 24 if smoke else 96
    chunk = 2                                  # many chunks -> many guards
    adapter, train, _ = lm_task(0, n_train=512, n_test=256)
    phase = PhaseConfig(batch_size=32, max_steps=steps,
                        schedule=ScheduleConfig(kind="const", peak_lr=0.1))
    run = SGDRun(adapter, phase, train)

    def fresh():
        # a fresh bundle per run: the chunk program donates state buffers,
        # so a shared bundle would be dead after the first pass
        return run.init_state(adapter.init(jax.random.PRNGKey(0)))

    sup = PhaseSupervisor(SupervisorConfig())
    # one warm pass each: the chunk program compiles once per runner, the
    # guard's all-finite reduction once per supervisor pass shape
    run_phase(run.runner, fresh(), 0, max_steps=steps, chunk_steps=chunk)
    sup.run_phase(run.runner, fresh(), 0, max_steps=steps, tag="phase1",
                  chunk_steps=chunk)
    bare = run_phase(run.runner, fresh(), 0, max_steps=steps,
                     chunk_steps=chunk)
    guarded = sup.run_phase(run.runner, fresh(), 0, max_steps=steps,
                            tag="phase1", chunk_steps=chunk)
    return bare.train_time, guarded.train_time, steps


def bench_death_recovery(smoke: bool):
    """Wall time of the chaos drill vs its no-fault twin; returns a dict
    with the completion verdict and the recovery cost in seconds."""
    phase2_steps = 4 if smoke else 8
    adapter, train, test_loader = lm_task(0, n_train=128, n_test=256)

    def one_run(inject: bool):
        tmp = tempfile.mkdtemp(prefix="bench_resilience_")
        clock = FakeClock()
        plan = FaultPlan(clock)
        if inject:
            plan.kill_worker(3, at_step=phase2_steps // 2)
        writers = [HeartbeatWriter(f"{tmp}/hb", w, clock=clock)
                   for w in range(4)]
        for w in writers:
            w.beat()
        monitor = HeartbeatMonitor(f"{tmp}/hb", 4, timeout_s=1.5,
                                   clock=clock)
        sup = PhaseSupervisor(SupervisorConfig(max_retries=2),
                              monitor=monitor, sleep=lambda s: None)
        cfg = SWAPConfig(
            n_workers=4,
            phase1=PhaseConfig(batch_size=32, max_steps=2,
                               schedule=ScheduleConfig(kind="const",
                                                       peak_lr=0.1)),
            phase2=PhaseConfig(batch_size=16, max_steps=phase2_steps,
                               schedule=ScheduleConfig(kind="const",
                                                       peak_lr=0.05)),
            bn_recompute_batch_size=64,
            checkpoint_dir=f"{tmp}/ckpts", checkpoint_every=1)
        swap = SWAP(adapter, cfg, train, test_loader,
                    dist=DistConfig(n_workers=4, elastic_deadline_s=30.0),
                    supervisor=sup)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = swap.run(jax.random.PRNGKey(0), collect_curves=True,
                           phase2_hooks=[plan.beat_hook(writers)],
                           heartbeats=monitor)
        return time.perf_counter() - t0, res

    clean_s, _ = one_run(inject=False)
    faulted_s, res = one_run(inject=True)
    events = res["recovery_events"]
    completed = (res["phase2_steps"] == phase2_steps
                 and res["phase2_live_workers"] == 3
                 and res["worker_live_mask"] == [True, True, True, False]
                 and len(events) == 1 and events[0]["kind"] == "worker_lost")
    return {
        "completed": bool(completed),
        "clean_wall_s": round(clean_s, 3),
        "faulted_wall_s": round(faulted_s, 3),
        # restore + replay cost of the one recovery (same process, same
        # compiled programs — the difference IS the recovery)
        "time_to_recover_s": round(max(faulted_s - clean_s, 0.0), 3),
        "survivor_mean_acc": round(res["before_avg_test_acc"], 4),
        "averaged_acc": round(res["after_avg_test_acc"], 4),
        "recovery_events": events,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--out", default="BENCH_resilience.json")
    args = ap.parse_args()

    bare_s, sup_s, steps = bench_overhead(args.smoke)
    ratio = bare_s / sup_s if sup_s > 0 else 0.0
    recovery = bench_death_recovery(args.smoke)

    out = {
        "config": {"smoke": args.smoke, "overhead_steps": steps,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "overhead": {"bare_train_s": round(bare_s, 3),
                     "supervised_train_s": round(sup_s, 3),
                     "supervised_overhead_ratio": round(ratio, 3)},
        "death_recovery": recovery,
        # consumed by benchmarks/check_regression.py (CI bench job).
        # supervised_overhead_ratio: bare/supervised hot-path time on a
        # zero-fault run — the guard may cost at most ~40%. The recovery
        # drill is pass/fail: a supervised run through a mid-phase-2
        # worker death must complete with the surviving ensemble.
        "tracked": {
            "supervised_overhead_ratio": {"value": round(ratio, 3),
                                          "floor": 0.6},
            "death_recovery_completed": {
                "value": 1.0 if recovery["completed"] else 0.0,
                "floor": 1.0, "stable": True},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
