"""Figure 4 analog: cosine similarity between the descent direction (-g)
and the direction to the final SWAP point, along a worker's phase-2
trajectory. Paper: the similarity DECAYS in late training (the iterate moves
mostly orthogonally to the basin-center direction)."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import cnn_task
from repro.configs.base import ScheduleConfig
from repro.core.averaging import average_list
from repro.core.schedules import schedule_fn
from repro.data.pipeline import Loader
from repro.train.precision import default_scale_state

STEPS = 240        # long enough that training actually converges — the
                   # decay is a LATE-training phenomenon (paper Fig. 4)


def _flat(tree):
    return jnp.concatenate([l.reshape(-1)
                            for l in jax.tree_util.tree_leaves(tree)])


def run(verbose=True):
    adapter, train, test_loader = cnn_task(seed=0, noise=3.5)
    loader = Loader(train, 64, seed=3)
    sched = schedule_fn(ScheduleConfig(kind="warmup_linear", peak_lr=0.2,
                                       warmup_steps=24, total_steps=STEPS,
                                       end_lr=0.02))
    step_fn = jax.jit(adapter.make_train_step(sched))

    bundle = adapter.init(jax.random.PRNGKey(0))
    opt_state = adapter.init_opt(bundle)
    scale = default_scale_state()

    # record trajectory + gradients
    params_hist, grads_hist = [], []
    grad_fn = jax.jit(jax.grad(
        lambda p, s, b: adapter._loss(p, s, b)[0]))
    for step in range(STEPS):
        batch = loader.batch(step)
        params_hist.append(bundle["params"])
        grads_hist.append(grad_fn(bundle["params"], bundle["state"], batch))
        bundle, opt_state, scale, _ = step_fn(bundle, opt_state, batch,
                                              step, scale)

    # SWAP point: average of tail iterates (stand-in for the worker average)
    theta_swap = _flat(average_list(params_hist[STEPS // 2:]))

    sims = []
    for t in range(STEPS):
        g = _flat(grads_hist[t])
        d = theta_swap - _flat(params_hist[t])
        sims.append(float(jnp.vdot(-g, d)
                          / (jnp.linalg.norm(g) * jnp.linalg.norm(d) + 1e-12)))
    # compare mid-training (past warmup, approaching the basin) vs late
    early = sum(sims[STEPS // 4:STEPS // 2]) / (STEPS // 4)
    late = sum(sims[-STEPS // 4:]) / (STEPS // 4)
    if verbose:
        print("\n== Figure 4 analog (cosine similarity decay) ==")
        for t in range(0, STEPS, max(1, STEPS // 12)):
            print(f"step {t:3d}: cos = {sims[t]: .4f}")
        print(f"early-mean {early:.4f} -> late-mean {late:.4f} "
              f"(paper: decays toward ~0)")
    return {"sims": sims, "early_mean": early, "late_mean": late}


def main():
    out = run()
    with open("results/figure4.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
