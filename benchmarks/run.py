"""Benchmark aggregator — one entry per paper table/figure + roofline +
kernel microbench. Prints ``name,us_per_call,derived`` CSV at the end.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1 ...]
"""
from __future__ import annotations

import argparse
import os
import statistics
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single seed per table")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    os.makedirs("results", exist_ok=True)
    seeds = (0,) if args.quick else (0, 1, 2)
    csv = []

    def want(name):
        return args.only is None or name in args.only

    def record(name, secs, derived=""):
        csv.append(f"{name},{secs*1e6:.0f},{derived}")

    def acc_of(table, row):
        return statistics.mean(table[row]["acc"])

    if want("table1"):
        from benchmarks import table1_cifar10
        t0 = time.perf_counter()
        t1 = table1_cifar10.run(seeds=seeds)
        record("table1_cifar10", time.perf_counter() - t0,
               f"swap_after={acc_of(t1, 'SWAP (after averaging)'):.4f};"
               f"small={acc_of(t1, 'SGD (small-batch)'):.4f};"
               f"large={acc_of(t1, 'SGD (large-batch)'):.4f}")
        import json
        json.dump(t1, open("results/table1.json", "w"), indent=1)

    if want("table2"):
        from benchmarks import table2_cifar100
        t0 = time.perf_counter()
        t2 = table2_cifar100.run(seeds=seeds)
        record("table2_cifar100", time.perf_counter() - t0,
               f"swap_after={acc_of(t2, 'SWAP (after averaging)'):.4f};"
               f"small={acc_of(t2, 'SGD (small-batch)'):.4f}")
        import json
        json.dump(t2, open("results/table2.json", "w"), indent=1)

    if want("table3"):
        from benchmarks import table3_imagenet
        t0 = time.perf_counter()
        t3 = table3_imagenet.run(seeds=seeds)
        record("table3_imagenet", time.perf_counter() - t0,
               f"swap_after={acc_of(t3, 'SWAP (after averaging)'):.4f}")
        import json
        json.dump(t3, open("results/table3.json", "w"), indent=1)

    if want("table4"):
        from benchmarks import table4_swa_vs_swap
        t0 = time.perf_counter()
        t4 = table4_swa_vs_swap.run(seeds=seeds[:2] if len(seeds) > 1
                                    else seeds)
        seq = statistics.mean(t4["LB followed by small-batch SWA"]["time"])
        par = statistics.mean(t4["SWAP (1-cycle workers)"]["time"])
        record("table4_swa_vs_swap", time.perf_counter() - t0,
               f"swa_over_swap_time={seq/par:.2f}x")
        import json
        json.dump(t4, open("results/table4.json", "w"), indent=1)

    if want("figure1"):
        from benchmarks import figure1_curves
        t0 = time.perf_counter()
        f1 = figure1_curves.run()
        record("figure1_curves", time.perf_counter() - t0,
               f"late_steps_avg_above_best={f1['late_steps_avg_above_best']}")
        import json
        json.dump(f1, open("results/figure1.json", "w"), indent=1)

    if want("figure23"):
        from benchmarks import figure23_landscape
        t0 = time.perf_counter()
        f23 = figure23_landscape.run()
        record("figure23_landscape", time.perf_counter() - t0,
               f"test_err_swap={f23['test_err']['SWAP']:.3f};"
               f"test_err_lb={f23['test_err']['LB']:.3f}")
        import json
        json.dump(f23, open("results/figure23.json", "w"), indent=1)

    if want("figure4"):
        from benchmarks import figure4_cosine
        t0 = time.perf_counter()
        f4 = figure4_cosine.run()
        record("figure4_cosine", time.perf_counter() - t0,
               f"early={f4['early_mean']:.3f};late={f4['late_mean']:.3f}")
        import json
        json.dump(f4, open("results/figure4.json", "w"), indent=1)

    if want("roofline"):
        from benchmarks import roofline
        roofline.run(mesh="single")
        roofline.run(mesh="multi")

    if want("microbench"):
        from benchmarks import microbench
        rows = microbench.run()
        csv.extend(rows)

    if args.only and "ablation" in args.only:
        # beyond-paper worker-count ablation (opt-in: ~15 min)
        from benchmarks import ablation_workers
        t0 = time.perf_counter()
        ab = ablation_workers.run()
        import json
        json.dump(ab, open("results/ablation_workers.json", "w"), indent=1)
        record("ablation_workers", time.perf_counter() - t0)

    print("\n== CSV (name,us_per_call,derived) ==")
    for row in csv:
        print(row)


if __name__ == "__main__":
    main()
