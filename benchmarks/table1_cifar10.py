"""Table 1 analog (CIFAR10): small-batch vs large-batch vs SWAP on the
CNN+BN model over the synthetic image task.

Paper (CIFAR10): small 95.24 / 254s; large 94.77 / 133s; SWAP(before) 94.70
/ 168s; SWAP(after) 95.23 / 169s. We reproduce the ordering:
  acc: SWAP(after) ~ small > large ~ SWAP(before);
  time: SWAP ~ large << small.
"""
from __future__ import annotations

import json

from benchmarks.common import cnn_task, mean_std, run_sgd, run_swap

# Grid-searched like the paper (Appendix A): small-batch 20 epochs at
# lr 0.4; large-batch 30 epochs (paper uses 1.5x epochs for LB) at lr 1.2
# (~linear scaling of 8x batch, paper: 0.3 -> 1.2); SWAP phase 1 stops at
# 93% train accuracy, phase 2 runs 8 workers at the small batch size.
SMALL = dict(batch_size=64, steps=640, peak_lr=0.4)
LARGE = dict(batch_size=512, steps=120, peak_lr=1.2)
SWAP_HP = dict(workers=8, b1=512, b2=64, steps1=120, steps2=96,
               lr1=1.2, lr2=0.15, stop_acc=0.93)
NOISE = 3.5


def run(seeds=(0, 1, 2), verbose=True):
    rows = {"SGD (small-batch)": [], "SGD (large-batch)": [],
            "SWAP (before averaging)": [], "SWAP (after averaging)": []}
    times = {k: [] for k in rows}
    updates = {k: [] for k in rows}
    for seed in seeds:
        adapter, train, test_loader = cnn_task(seed=seed, noise=NOISE)
        small = run_sgd(adapter, train, test_loader, seed=seed, **SMALL)
        large = run_sgd(adapter, train, test_loader, seed=seed, **LARGE)
        swap = run_swap(adapter, train, test_loader, seed=seed, **SWAP_HP)
        rows["SGD (small-batch)"].append(small["test_acc"])
        rows["SGD (large-batch)"].append(large["test_acc"])
        rows["SWAP (before averaging)"].append(swap["before_avg_test_acc"])
        rows["SWAP (after averaging)"].append(swap["after_avg_test_acc"])
        times["SGD (small-batch)"].append(small["time"])
        times["SGD (large-batch)"].append(large["time"])
        swap_t = swap["phase1_time"] + swap["phase2_time"]
        times["SWAP (before averaging)"].append(swap_t)
        times["SWAP (after averaging)"].append(swap_t + swap["phase3_time"])
        # sequential update counts — the scaling-relevant time proxy (a
        # single CPU can't reward parallelism; per-update target-hardware
        # cost comes from the §Roofline table)
        updates["SGD (small-batch)"].append(small["steps"])
        updates["SGD (large-batch)"].append(large["steps"])
        swap_u = swap["phase1_steps"] + SWAP_HP["steps2"]
        updates["SWAP (before averaging)"].append(swap_u)
        updates["SWAP (after averaging)"].append(swap_u)
    out = {}
    if verbose:
        print("\n== Table 1 analog (CIFAR10 / CNN+BN on synthetic images) ==")
        print(f"{'row':28s} {'test acc':>20s} {'time (s)':>18s} "
              f"{'updates':>9s}")
    for k in rows:
        out[k] = {"acc": rows[k], "time": times[k], "updates": updates[k]}
        if verbose:
            u = int(sum(updates[k]) / len(updates[k]))
            print(f"{k:28s} {mean_std(rows[k]):>20s} "
                  f"{mean_std(times[k]):>18s} {u:>9d}")
    return out


def main():
    out = run()
    with open("results/table1.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
