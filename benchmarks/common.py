"""Shared benchmark scaffolding: synthetic CIFAR/LM analogs + SWAP/SGD/SWA
runners with paper-shaped hyper-parameter schedules.

The paper's absolute numbers are V100/CIFAR-specific; these benchmarks
reproduce the CLAIM STRUCTURE (orderings and time ratios) on synthetic data:
  - small-batch > large-batch test accuracy at equal epochs,
  - SWAP(after avg) ~ small-batch accuracy at ~large-batch wall-clock,
  - SWAP beats every individual phase-2 worker,
  - sequential SWA needs a multiple of SWAP's time for the same quality.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

import jax

from repro.configs import registry
from repro.configs.base import (OptimizerConfig, PhaseConfig,
                                ScheduleConfig, SWAConfig, SWAPConfig)
from repro.core.adapters import CNNAdapter, LMAdapter
from repro.core.swa import SWA
from repro.core.swap import SWAP, SGDRun
from repro.data.pipeline import Loader, make_gmm_images, make_markov_lm


# ---------------------------------------------------------------------------
# tasks
# ---------------------------------------------------------------------------


def cnn_task(seed: int = 0, n_classes: int = 10, noise: float = 2.0,
             n_train: int = 2048, n_test: int = 1024):
    cfg = registry.get_smoke_config("cifar-cnn")
    data = make_gmm_images(seed, n_classes=n_classes, image_size=16,
                           n_train=n_train, n_test=n_test, noise=noise)
    train = {"images": data["train_images"], "labels": data["train_labels"]}
    test_loader = Loader({"images": data["test_images"],
                          "labels": data["test_labels"]}, 256)
    adapter = CNNAdapter(cfg, OptimizerConfig(kind="sgd", momentum=0.9,
                                              weight_decay=5e-4))
    return adapter, train, test_loader


def lm_task(seed: int = 0, arch: str = "internlm2-1.8b", seq_len: int = 32,
            n_train: int = 2048, n_test: int = 512,
            temperature: float = 0.15):
    cfg = registry.get_smoke_config(arch)
    data = make_markov_lm(seed, vocab=min(cfg.vocab_size, 256),
                          n_train=n_train, n_test=n_test, seq_len=seq_len,
                          temperature=temperature)
    train = {"tokens": data["train_tokens"] % cfg.vocab_size,
             "labels": data["train_labels"] % cfg.vocab_size}
    test_loader = Loader({"tokens": data["test_tokens"] % cfg.vocab_size,
                          "labels": data["test_labels"] % cfg.vocab_size},
                         256)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd", momentum=0.9,
                                             weight_decay=5e-4))
    return adapter, train, test_loader


# ---------------------------------------------------------------------------
# runners
# ---------------------------------------------------------------------------


def run_sgd(adapter, train, test_loader, *, batch_size: int, steps: int,
            peak_lr: float, warmup_frac: float = 0.2, seed: int = 0,
            stop_accuracy: float = 1.01) -> Dict:
    """One plain SGD training run (small-batch or large-batch baseline)."""
    phase = PhaseConfig(
        batch_size=batch_size, max_steps=steps, stop_accuracy=stop_accuracy,
        schedule=ScheduleConfig(kind="warmup_linear", peak_lr=peak_lr,
                                warmup_steps=int(steps * warmup_frac),
                                total_steps=steps))
    run = SGDRun(adapter, phase, train, seed=seed)
    bundle = adapter.init(jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    bundle, opt_state, taken, ema = run.run(bundle)
    t1 = time.perf_counter()
    return {"test_acc": adapter.eval_accuracy(bundle, test_loader),
            "train_ema": ema, "steps": taken, "time": t1 - t0,
            "bundle": bundle, "opt_state": opt_state}


def run_swap(adapter, train, test_loader, *, workers: int, b1: int, b2: int,
             steps1: int, steps2: int, lr1: float, lr2: float,
             stop_acc: float, seed: int = 0,
             collect_curves: bool = False) -> Dict:
    cfg = SWAPConfig(
        n_workers=workers,
        phase1=PhaseConfig(batch_size=b1, max_steps=steps1,
                           stop_accuracy=stop_acc,
                           schedule=ScheduleConfig(
                               kind="warmup_linear", peak_lr=lr1,
                               warmup_steps=max(1, steps1 // 5),
                               total_steps=steps1)),
        phase2=PhaseConfig(batch_size=b2, max_steps=steps2,
                           schedule=ScheduleConfig(
                               kind="warmup_linear", peak_lr=lr2,
                               warmup_steps=0, total_steps=steps2)),
        bn_recompute_batches=4, bn_recompute_batch_size=256, seed=seed)
    return SWAP(adapter, cfg, train, test_loader).run(
        jax.random.PRNGKey(seed), collect_curves=collect_curves)


def run_swa(adapter, train, test_loader, *, start_bundle, n_samples: int,
            cycle_steps: int, batch_size: int, peak_lr: float,
            seed: int = 0) -> Dict:
    cfg = SWAConfig(
        n_samples=n_samples, cycle_steps=cycle_steps, batch_size=batch_size,
        schedule=ScheduleConfig(kind="cyclic", peak_lr=peak_lr,
                                min_lr=peak_lr * 0.1,
                                cycle_steps=cycle_steps),
        seed=seed)
    return SWA(adapter, cfg, train, test_loader).run(start_bundle)


# ---------------------------------------------------------------------------
# kernel timing
# ---------------------------------------------------------------------------


def time_kernel(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Steady-state seconds per call: ``warmup`` untimed calls (compile +
    cache warm), then the mean of ``iters`` block-until-ready timed calls.
    The one shared timing helper for microbench.py and bench_kernels.py —
    keep warmup/steady-state policy changes here."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------


def mean_std(vals: List[float]) -> str:
    if len(vals) == 1:
        return f"{vals[0]:.4f}"
    return f"{statistics.mean(vals):.4f} ± {statistics.stdev(vals):.4f}"


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
