"""Phase-engine throughput: per-step Python loop vs scan-based epoch runner.

Measures steps/sec for the two execution engines on the same task, model,
and data ordering:

  * ``python-loop`` — the engine this PR replaced: one jitted step dispatch
    per Python iteration; phase 2 additionally rebuilds and stacks W worker
    batches on the host every step.
  * ``scan`` — ``repro.train.loop.EpochRunner``: the whole epoch scanned
    inside one jit, worker batches gathered in-trace from device-resident
    arrays (vmapped over the worker axis for phase 2).

Compile time is excluded from both sides (one warmup pass each). Emits
``BENCH_train_loop.json``; the acceptance bar is >= 2x phase-2 steps/sec
for the scan engine on the CPU smoke config.

  PYTHONPATH=src python benchmarks/bench_train_loop.py --smoke \
      [--out BENCH_train_loop.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, ScheduleConfig
from repro.core.adapters import LMAdapter
from repro.core.schedules import schedule_fn
from repro.core.swap import _stack_bundles
from repro.data.pipeline import Loader, make_markov_lm
from repro.train.loop import (EpochRunner, init_train_state,
                              python_loop_reference, stack_host_batches,
                              stack_train_state)
from repro.train.precision import default_scale_state, stack_scale_state


def bench_model(smoke: bool) -> ModelConfig:
    """Small dense LM. The engines run identical per-step math; they differ
    in host dispatch / batch-building overhead, so the benchmark sizes the
    step to be cheap — the regime the engine targets (on an accelerator the
    step IS cheap relative to the host loop; a big model on this CPU host
    would just hide the loop behind arithmetic)."""
    scale = 1 if smoke else 2
    return ModelConfig(
        name="bench-lm", family="dense", n_layers=2,
        d_model=32 * scale, n_heads=4, n_kv_heads=2, head_dim=8 * scale,
        d_ff=64 * scale, vocab_size=32, attention="gqa", dtype="float32",
        remat=False, scan_layers=False)


def _time_python_phase1(step_fn, loader, adapter, steps: int) -> float:
    bundle = adapter.init(jax.random.PRNGKey(0))
    state = init_train_state(bundle, adapter.init_opt(bundle))
    # warmup pass (compile), then the timed run from a fresh state
    python_loop_reference(step_fn, loader, state, n_steps=min(4, steps),
                          ema_beta=0.9)
    bundle = adapter.init(jax.random.PRNGKey(0))
    state = init_train_state(bundle, adapter.init_opt(bundle))
    t0 = time.perf_counter()
    python_loop_reference(step_fn, loader, state, n_steps=steps, ema_beta=0.9)
    return steps / (time.perf_counter() - t0)


def _time_scan_phase1(step_fn, loader, adapter, steps: int) -> float:
    runner = EpochRunner(step_fn, loader, 0.9)
    spe = loader.steps_per_epoch

    def fresh():
        bundle = adapter.init(jax.random.PRNGKey(0))
        return init_train_state(bundle, adapter.init_opt(bundle))

    def run(state):
        done = 0
        while done < steps:
            n = min(spe, steps - done)
            state, _ = runner.run_chunk(state, 0, n)
            done += n
        jax.block_until_ready(state.bundle)

    run(fresh())                       # warmup: compiles both chunk lengths
    state = fresh()
    t0 = time.perf_counter()
    run(state)
    return steps / (time.perf_counter() - t0)


def _phase2_setup(adapter, loader, n_workers: int):
    bundle = adapter.init(jax.random.PRNGKey(0))
    stacked = _stack_bundles(bundle, n_workers)
    opt = jax.vmap(adapter.init_opt)(stacked)
    return stack_train_state(stacked, opt, n_workers)


def _time_python_phase2(step_fn, loader, adapter, steps: int,
                        n_workers: int) -> float:
    """The replaced SWAP phase-2 loop: host builds + stacks W batches, then
    dispatches one jitted vmapped step, every step."""
    ens_step = jax.jit(jax.vmap(step_fn, in_axes=(0, 0, 0, None, 0)),
                       donate_argnums=(0, 1))

    def run(state, n):
        stacked, opt = state.bundle, state.opt_state
        scale = stack_scale_state(default_scale_state(), n_workers)
        for step in range(n):
            batches = stack_host_batches(loader, step, n_workers)
            stacked, opt, scale, _ = ens_step(stacked, opt, batches, step,
                                              scale)
        jax.block_until_ready(stacked)

    run(_phase2_setup(adapter, loader, n_workers), min(4, steps))  # warmup
    # state assembly happens OUTSIDE the timer on both sides: this measures
    # the steady-state step rate, not one-time setup
    state = _phase2_setup(adapter, loader, n_workers)
    t0 = time.perf_counter()
    run(state, steps)
    return steps / (time.perf_counter() - t0)


def _time_scan_phase2(step_fn, loader, adapter, steps: int,
                      n_workers: int) -> float:
    runner = EpochRunner(step_fn, loader, 0.9, ensemble=True)
    workers = jnp.arange(n_workers, dtype=jnp.int32)
    spe = loader.steps_per_epoch

    def run(state):
        done = 0
        while done < steps:
            n = min(spe, steps - done)
            state, _ = runner.run_chunk(state, workers, n)
            done += n
        jax.block_until_ready(state.bundle)

    run(_phase2_setup(adapter, loader, n_workers))     # warmup
    state = _phase2_setup(adapter, loader, n_workers)
    t0 = time.perf_counter()
    run(state)
    return steps / (time.perf_counter() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=0,
                    help="steps per engine (default: 128 smoke / 256 full)")
    ap.add_argument("--out", default="BENCH_train_loop.json")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if phase-2 scan speedup falls below "
                         "this (0 = report only). The acceptance baseline "
                         "was measured at 2x+; CI uses a lower bar to "
                         "tolerate shared-runner noise while still catching "
                         "a scan engine that regresses below the old loop")
    args = ap.parse_args()

    steps = args.steps or (128 if args.smoke else 256)
    cfg = bench_model(args.smoke)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=512, n_test=64,
                          seq_len=16 if args.smoke else 32)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    step_fn = adapter.make_train_step(
        schedule_fn(ScheduleConfig(kind="const", peak_lr=0.05)))

    loader1 = Loader(train, 32, seed=0)
    p1_py = _time_python_phase1(step_fn, loader1, adapter, steps)
    p1_scan = _time_scan_phase1(step_fn, loader1, adapter, steps)

    loader2 = Loader(train, 8, seed=1)
    p2_py = _time_python_phase2(step_fn, loader2, adapter, steps,
                                args.workers)
    p2_scan = _time_scan_phase2(step_fn, loader2, adapter, steps,
                                args.workers)

    out = {
        "config": {"model": cfg.name, "params": cfg.param_count(),
                   "smoke": args.smoke, "workers": args.workers,
                   "steps": steps, "phase1_batch": loader1.batch_size,
                   "phase2_batch_per_worker": loader2.batch_size,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "phase1": {"python_steps_per_sec": round(p1_py, 2),
                   "scan_steps_per_sec": round(p1_scan, 2),
                   "speedup": round(p1_scan / p1_py, 2)},
        "phase2": {"python_steps_per_sec": round(p2_py, 2),
                   "scan_steps_per_sec": round(p2_scan, 2),
                   "speedup": round(p2_scan / p2_py, 2)},
        # contract consumed by benchmarks/check_regression.py (CI bench
        # job): each tracked metric must land at or above its floor; floors
        # sit well under the checked-in values to tolerate shared-runner
        # noise while still catching a real regression
        "tracked": {
            "phase1_speedup": {"value": round(p1_scan / p1_py, 2),
                               "floor": 1.0},
            "phase2_speedup": {"value": round(p2_scan / p2_py, 2),
                               "floor": 1.2},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    if args.min_speedup and out["phase2"]["speedup"] < args.min_speedup:
        raise SystemExit(
            f"phase-2 scan speedup {out['phase2']['speedup']}x below the "
            f"{args.min_speedup}x bar")


if __name__ == "__main__":
    main()
