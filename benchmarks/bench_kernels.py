"""Kernel design-point sweep driver: the autotuner behind tuning_cache.json.

Enumerates design points (block_q/block_k/num_warps/num_stages per kernel)
over shape buckets, times each with the shared steady-state helper
(benchmarks.common.time_kernel), scores achieved time against the
benchmarks.roofline analytical bound, and — with ``--update-cache`` —
persists each bucket's winner into ``src/repro/kernels/tuning_cache.json``
under the ``backend/kernel/bucket`` key that ``dispatch.resolve`` consults.

Modes:
  --smoke   CI mode: 2 design points per kernel, tiny shapes, the forced
            native-variant kernel under the Pallas interpreter on CPU.
            Exists to exercise the sweep machinery + tracked floors every
            push, not to produce meaningful tunings.
  (default) full sweep on the live backend (run on a real GPU/TPU host,
            then commit the refreshed cache).

Tracked metrics (BENCH_kernels.json contract, enforced by
check_regression.py in CI):
  {kernel}_best_vs_default   default-design time / best time. >= 1.0 by
                             construction (the default is always in the
                             candidate set), so the floor pins the sweep
                             machinery, not runner speed.
  {kernel}_roofline_fraction roofline bound / best time (fraction of
                             analytical peak achieved). Floor 0.0 —
                             recorded for trajectory, meaningless under
                             the CPU interpreter.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

# runnable both as `python benchmarks/bench_kernels.py` (CI) and as a module
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import time_kernel
from benchmarks.roofline import kernel_bound_s
from repro.kernels import dispatch, tuning
from repro.kernels.tuning import DEFAULT_DESIGN, DesignPoint

# ---------------------------------------------------------------------------
# design-point candidate spaces (the default design MUST stay first:
# best_vs_default >= 1.0 relies on it being in the swept set)
# ---------------------------------------------------------------------------

FULL_SPACE = {
    "flash_attention": [DEFAULT_DESIGN["flash_attention"]] + [
        DesignPoint(bq, bk, w, st)
        for bq in (64, 128) for bk in (64, 128)
        for w in (4, 8) for st in (2, 3)
        if (bq, bk, w, st) != (128, 128, 4, 2)
    ],
    "ssd": [DEFAULT_DESIGN["ssd"]] + [
        DesignPoint(0, 0, w, st)
        for w in (2, 4, 8) for st in (1, 2, 3)
        if (w, st) != (4, 2)
    ],
    "swa_avg": [DEFAULT_DESIGN["swa_avg"]] + [
        DesignPoint(bq, 0, w, 2)
        for bq in (4096, 8192, 16384, 32768) for w in (4, 8)
        if (bq, w) != (8192, 4)
    ],
}

SMOKE_SPACE = {
    "flash_attention": [DEFAULT_DESIGN["flash_attention"],
                        DesignPoint(32, 32, 8, 2)],
    "ssd": [DEFAULT_DESIGN["ssd"], DesignPoint(0, 0, 8, 1)],
    "swa_avg": [DEFAULT_DESIGN["swa_avg"],
                DesignPoint(16384, 0, 8, 2)],
}

# (shape kwargs for roofline.kernel_model) per mode
SMOKE_SHAPES = {
    "flash_attention": dict(b=1, sq=64, skv=64, h=4, kvh=2, d=16),
    "ssd": dict(b=1, s=64, h=2, p=16, n=16, chunk=32),
    "swa_avg": dict(numel=65536),
}
FULL_SHAPES = {
    "flash_attention": dict(b=4, sq=2048, skv=2048, h=16, kvh=4, d=128),
    "ssd": dict(b=4, s=2048, h=16, p=64, n=128, chunk=128),
    "swa_avg": dict(numel=50_000_000),
}


def _bucket_shape(kernel: str, s: dict):
    """The tuning.shape_bucket tuple for a bench shape."""
    if kernel == "flash_attention":
        return (s["skv"], s["d"])
    if kernel == "ssd":
        return (s["s"], s["p"])
    return (s["numel"],)


# ---------------------------------------------------------------------------
# per-kernel timed calls (forced native variant; interpreter off-GPU)
# ---------------------------------------------------------------------------


def _flash_fn(s, design, variant, interpret):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (s["b"], s["sq"], s["h"], s["d"]))
    k = jax.random.normal(key, (s["b"], s["skv"], s["kvh"], s["d"]))
    v = jax.random.normal(key, (s["b"], s["skv"], s["kvh"], s["d"]))
    if variant == "triton":
        from repro.kernels.flash_attention.kernel_gpu import (
            flash_attention_triton)
        fn = lambda q, k, v: flash_attention_triton(
            q, k, v, design=design, interpret=interpret)
    else:
        from repro.kernels.flash_attention.kernel import (
            flash_attention_pallas)
        bq = design.block_q or 128
        bk = design.block_k or 128
        fn = lambda q, k, v: flash_attention_pallas(
            q, k, v, block_q=bq, block_k=bk, interpret=interpret)
    return fn, (q, k, v)


def _ssd_fn(s, design, variant, interpret):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (s["b"], s["s"], s["h"], s["p"]))
    dt = jax.nn.softplus(jax.random.normal(key, (s["b"], s["s"], s["h"])))
    A = -jnp.abs(jax.random.normal(key, (s["h"],)))
    Bm = jax.random.normal(key, (s["b"], s["s"], 1, s["n"]))
    Cm = jax.random.normal(key, (s["b"], s["s"], 1, s["n"]))
    if variant == "triton":
        from repro.kernels.ssd.kernel_gpu import ssd_chunk_triton
        fn = lambda *a: ssd_chunk_triton(*a, chunk=s["chunk"],
                                         design=design,
                                         interpret=interpret)
    else:
        from repro.kernels.ssd.kernel import ssd_chunk_pallas
        fn = lambda *a: ssd_chunk_pallas(*a, chunk=s["chunk"],
                                         interpret=interpret)
    return fn, (x, dt, A, Bm, Cm)


def _swa_fn(s, design, variant, interpret):
    key = jax.random.PRNGKey(0)
    avg = jax.random.normal(key, (s["numel"],))
    w = jax.random.normal(jax.random.PRNGKey(1), (s["numel"],))
    n = jnp.float32(3.0)
    if variant == "triton":
        from repro.kernels.swa_avg.kernel_gpu import running_average_triton
        fn = lambda a, b, n: running_average_triton(
            a, b, n, design=design, interpret=interpret)
    else:
        from repro.kernels.swa_avg.kernel import running_average_pallas
        fn = lambda a, b, n: running_average_pallas(a, b, n,
                                                    interpret=interpret)
    return fn, (avg, w, n)


_BENCH_FNS = {"flash_attention": _flash_fn, "ssd": _ssd_fn,
              "swa_avg": _swa_fn}


def sweep_kernel(kernel: str, shapes: dict, space: list, backend: str,
                 variant: str, interpret: bool, iters: int) -> dict:
    s = shapes[kernel]
    bound = kernel_bound_s(kernel, backend, **s)
    results = []
    for dp in space:
        fn, args = _BENCH_FNS[kernel](s, dp, variant, interpret)
        t = time_kernel(fn, *args, iters=iters)
        results.append({"design": dp.astuple(), "time_us": t * 1e6,
                        "roofline_fraction": bound / t})
        if t < bound:
            print(f"  WARNING: {kernel} {dp.astuple()} measured "
                  f"{t*1e6:.1f}us beats the roofline bound "
                  f"{bound*1e6:.1f}us — model or timer is wrong")
    best = min(results, key=lambda r: r["time_us"])
    default_t = results[0]["time_us"]   # default design is always first
    return {
        "shape": s, "bucket": tuning.shape_bucket(
            kernel, _bucket_shape(kernel, s)),
        "roofline_bound_us": bound * 1e6,
        "results": results,
        "best_design": best["design"],
        "best_time_us": best["time_us"],
        "default_time_us": default_t,
        "best_vs_default": default_t / best["time_us"],
        "roofline_fraction": best["roofline_fraction"],
    }


def run(smoke: bool = False, iters: int = 5, update_cache: bool = False,
        out: str | None = None, verbose: bool = True) -> dict:
    backend = dispatch.current_backend()
    # sweep the backend's native lowering; on CPU (smoke/CI) exercise the
    # Triton programs under the interpreter — the GPU path is the one with
    # a design-point space worth sweeping
    variant = {"tpu": "mosaic"}.get(backend, "triton")
    interpret = backend == "cpu" or (
        variant == "triton" and backend != "gpu")
    space = SMOKE_SPACE if smoke else FULL_SPACE
    shapes = SMOKE_SHAPES if smoke else FULL_SHAPES

    report = {"backend": backend, "variant": variant,
              "interpret": interpret,
              "mode": "smoke" if smoke else "full", "kernels": {},
              "tracked": {}}
    winners = {}
    for kernel in tuning.KERNELS:
        if verbose:
            print(f"== {kernel} ({variant}, interpret={interpret}, "
                  f"{len(space[kernel])} design points) ==")
        r = sweep_kernel(kernel, shapes, space[kernel], backend, variant,
                         interpret, iters)
        report["kernels"][kernel] = r
        winners[f"{backend}/{kernel}/{r['bucket']}"] = DesignPoint(
            *r["best_design"])
        report["tracked"][f"{kernel}_best_vs_default"] = {
            "value": round(r["best_vs_default"], 4), "floor": 1.0}
        report["tracked"][f"{kernel}_roofline_fraction"] = {
            "value": round(r["roofline_fraction"], 6), "floor": 0.0}
        if verbose:
            for res in r["results"]:
                print(f"  {str(res['design']):22s} "
                      f"{res['time_us']:10.1f}us  "
                      f"{res['roofline_fraction']:8.5f} of roofline")
            print(f"  best {r['best_design']} "
                  f"({r['best_vs_default']:.3f}x default)")

    if update_cache:
        path = tuning.update_entries(winners)
        print(f"tuning cache updated: {path} "
              f"({len(winners)} {backend} entries)")
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 2 design points per kernel, tiny "
                         "shapes, interpret-mode on CPU")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--update-cache", action="store_true",
                    help="persist per-bucket winners into "
                         "src/repro/kernels/tuning_cache.json")
    ap.add_argument("--out", help="write the sweep report JSON here")
    args = ap.parse_args()
    run(smoke=args.smoke, iters=args.iters, update_cache=args.update_cache,
        out=args.out)


if __name__ == "__main__":
    main()
