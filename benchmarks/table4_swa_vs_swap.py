"""Table 4 analog: SWA vs SWAP on the harder (CIFAR100-analog) task.

Paper rows:
  1. Large-batch SWA                 — cyclic LB sampling; averaging does NOT
                                       recover accuracy (76.06 -> 76.00)
  2. LB -> small-batch SWA           — recovers accuracy but sequentially:
                                       >3x SWAP's time (398s vs 125s)
  3. Small-batch SWA                 — best accuracy, 6.8x SWAP's time
  4. SWAP (10 small-batch epochs)    — 78.18 in 125s
  5. SWAP (40 small-batch epochs)    — 79.11 in 242s

We reproduce rows 1, 2, 4, 5 structure: same sample count for SWA and SWAP
(W models), same per-sample training budget; SWA runs them SEQUENTIALLY.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import cnn_task, mean_std, run_sgd, run_swa, run_swap

W = 8
CYCLE = 96                       # steps per sample (phase-2 budget analog)
LARGE = dict(batch_size=512, steps=120, peak_lr=1.2, stop_accuracy=0.88)
SWAP_HP = dict(workers=W, b1=512, b2=64, steps1=120, steps2=CYCLE,
               lr1=1.2, lr2=0.15, stop_acc=0.88)


def run(seeds=(0, 1), verbose=True):
    rows = {}

    def add(name, acc_b, acc_a, t):
        rows.setdefault(name, {"before": [], "after": [], "time": []})
        rows[name]["before"].append(acc_b)
        rows[name]["after"].append(acc_a)
        rows[name]["time"].append(t)

    for seed in seeds:
        adapter, train, test_loader = cnn_task(seed=seed, n_classes=20,
                                               noise=3.0)
        # ---- row 1: large-batch SWA (cyclic LB from scratch)
        t0 = time.perf_counter()
        lb = run_sgd(adapter, train, test_loader, seed=seed, **LARGE)
        swa_lb = run_swa(adapter, train, test_loader,
                         start_bundle=lb["bundle"], n_samples=W,
                         cycle_steps=CYCLE // 4, batch_size=512, peak_lr=0.6,
                         seed=seed)
        add("Large-batch SWA", swa_lb["before_avg_test_acc"],
            swa_lb["after_avg_test_acc"], time.perf_counter() - t0)

        # ---- row 2: LB then small-batch SWA (sequential refinement)
        t0 = time.perf_counter()
        lb2 = run_sgd(adapter, train, test_loader, seed=seed, **LARGE)
        swa_sb = run_swa(adapter, train, test_loader,
                         start_bundle=lb2["bundle"], n_samples=W,
                         cycle_steps=CYCLE, batch_size=64, peak_lr=0.15,
                         seed=seed)
        add("LB followed by small-batch SWA", swa_sb["before_avg_test_acc"],
            swa_sb["after_avg_test_acc"], time.perf_counter() - t0)

        # ---- row 4: SWAP, one cycle per worker (same W samples, parallel)
        swap = run_swap(adapter, train, test_loader, seed=seed, **SWAP_HP)
        add("SWAP (1-cycle workers)", swap["before_avg_test_acc"],
            swap["after_avg_test_acc"],
            swap["phase1_time"] + swap["phase2_time"] + swap["phase3_time"])

        # ---- row 5: SWAP with 4x phase-2 budget
        hp = dict(SWAP_HP, steps2=4 * CYCLE)
        swap4 = run_swap(adapter, train, test_loader, seed=seed, **hp)
        add("SWAP (4-cycle workers)", swap4["before_avg_test_acc"],
            swap4["after_avg_test_acc"],
            swap4["phase1_time"] + swap4["phase2_time"] + swap4["phase3_time"])

    # serial small-batch updates after phase 1: SWA samples W models
    # SEQUENTIALLY (W x CYCLE updates on one worker's critical path); SWAP
    # runs the W cycles in parallel (CYCLE updates of critical path). This
    # is the quantity a cluster's wall-clock follows; single-CPU wall-time
    # cannot reward parallelism (workers are simulated with vmap).
    rows["LB followed by small-batch SWA"]["serial_updates"] = W * CYCLE
    rows["SWAP (1-cycle workers)"]["serial_updates"] = CYCLE
    rows["SWAP (4-cycle workers)"]["serial_updates"] = 4 * CYCLE
    rows["Large-batch SWA"]["serial_updates"] = W * (CYCLE // 4)
    if verbose:
        print("\n== Table 4 analog (SWA vs SWAP) ==")
        print(f"{'row':34s} {'before avg':>18s} {'after avg':>18s} "
              f"{'time (s)':>14s} {'serial upd':>10s}")
        for k, v in rows.items():
            print(f"{k:34s} {mean_std(v['before']):>18s} "
                  f"{mean_std(v['after']):>18s} {mean_std(v['time']):>14s} "
                  f"{v['serial_updates']:>10d}")
        ratio = (rows["LB followed by small-batch SWA"]["serial_updates"]
                 / rows["SWAP (1-cycle workers)"]["serial_updates"])
        print(f"sequential-SWA / SWAP critical-path ratio: {ratio:.1f}x "
              f"(paper wall-clock: ~3.2x at W=8)")
    return rows


def main():
    out = run()
    with open("results/table4.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
