"""Precision / accumulation benchmark: steps/sec + compiled peak-memory
deltas for the phase-1 numerics configurations.

Four variants of the same train step on the same task and data ordering,
all through ``adapter.make_train_step`` + ``EpochRunner`` (the production
path):

  * ``f32``         — the pre-precision baseline (fused batch, f32 compute)
  * ``bf16``        — bf16 compute, f32 master weights (``BF16`` preset)
  * ``accum4``      — f32, the global batch as 4 sequential microbatches
  * ``bf16_accum4`` — both levers together

Speed is measured steps/sec (one warmup pass, compile excluded). Memory is
the compiled program's ``memory_analysis().temp_size_in_bytes`` — the
activation/workspace footprint, which is exactly what microbatch
accumulation (and the bf16 activation halving) targets; argument bytes
(params + device-resident data) are invariant across variants and reported
for context. The temp numbers come from the XLA buffer assigner and are
deterministic for a given config, so CI can track them tightly.

Emits ``BENCH_precision.json`` with a ``tracked`` section consumed by
``benchmarks/check_regression.py`` (CI bench job).

  PYTHONPATH=src python benchmarks/bench_precision.py --smoke \
      [--out BENCH_precision.json] [--min-mem-reduction 0.3]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs.base import ModelConfig, OptimizerConfig, ScheduleConfig
from repro.core.adapters import LMAdapter
from repro.core.schedules import schedule_fn
from repro.data.pipeline import Loader, make_markov_lm
from repro.train.loop import EpochRunner, init_train_state
from repro.train.precision import BF16, F32

VARIANTS = {
    "f32": (F32, 1),
    "bf16": (BF16, 1),
    "accum4": (F32, 4),
    "bf16_accum4": (BF16, 4),
}


def bench_model(smoke: bool) -> ModelConfig:
    """Sized so activations (batch x seq x width) dominate the 2-layer
    parameter set — the regime the memory levers target."""
    scale = 1 if smoke else 2
    return ModelConfig(
        name="bench-precision-lm", family="dense", n_layers=2,
        d_model=64 * scale, n_heads=4, n_kv_heads=2, head_dim=16 * scale,
        d_ff=128 * scale, vocab_size=64, attention="gqa", dtype="float32",
        remat=False, scan_layers=False)


def _bench_variant(adapter, loader, sched, policy, k, steps: int):
    step_fn = adapter.make_train_step(sched, policy=policy,
                                      grad_accum_steps=k)
    runner = EpochRunner(step_fn, loader, 0.9)
    spe = loader.steps_per_epoch

    def fresh():
        bundle = adapter.init(jax.random.PRNGKey(0))
        return init_train_state(bundle, adapter.init_opt(bundle),
                                scale=policy.init_scale_state())

    # static memory footprint of the compiled epoch chunk
    compiled = runner._chunk_fn(spe).lower(fresh(), 0).compile()
    ma = compiled.memory_analysis()
    if ma is None:
        # fail up front, not with a KeyError after the timed runs: the
        # tracked contract of this benchmark IS the memory deltas
        raise SystemExit(
            "compiled.memory_analysis() returned no data on this "
            "backend/jaxlib — bench_precision's tracked metrics are "
            "peak-memory reductions and cannot be produced here")
    mem = {"temp_bytes": int(ma.temp_size_in_bytes),
           "argument_bytes": int(ma.argument_size_in_bytes),
           "output_bytes": int(ma.output_size_in_bytes)}

    def run(state):
        done = 0
        while done < steps:
            n = min(spe, steps - done)
            state, _ = runner.run_chunk(state, 0, n)
            done += n
        jax.block_until_ready(state.bundle)

    run(fresh())                       # warmup: compiles both chunk lengths
    state = fresh()
    t0 = time.perf_counter()
    run(state)
    return dict(steps_per_sec=round(steps / (time.perf_counter() - t0), 2),
                **mem)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--steps", type=int, default=0,
                    help="steps per variant (default: 96 smoke / 192 full)")
    ap.add_argument("--out", default="BENCH_precision.json")
    ap.add_argument("--min-mem-reduction", type=float, default=0.0,
                    help="exit nonzero if the accum4 peak-memory reduction "
                         "vs f32 fused falls below this fraction (the "
                         "acceptance bar is 0.3; 0 = report only)")
    args = ap.parse_args()

    steps = args.steps or (96 if args.smoke else 192)
    cfg = bench_model(args.smoke)
    adapter = LMAdapter(cfg, OptimizerConfig(kind="sgd"))
    data = make_markov_lm(0, vocab=cfg.vocab_size, n_train=512, n_test=64,
                          seq_len=32 if args.smoke else 64)
    train = {"tokens": data["train_tokens"], "labels": data["train_labels"]}
    loader = Loader(train, 64, seed=0)
    sched = schedule_fn(ScheduleConfig(kind="const", peak_lr=0.05))

    variants = {}
    for name, (policy, k) in VARIANTS.items():
        variants[name] = _bench_variant(adapter, loader, sched, policy, k,
                                        steps)
        print(f"{name:12s} {variants[name]}")

    base = variants["f32"]
    for name, v in variants.items():
        if name == "f32":
            continue
        v["speedup_vs_f32"] = round(v["steps_per_sec"]
                                    / base["steps_per_sec"], 2)
        v["peak_mem_reduction_vs_f32"] = round(
            1.0 - v["temp_bytes"] / base["temp_bytes"], 3)

    out = {
        "config": {"model": cfg.name, "params": cfg.param_count(),
                   "smoke": args.smoke, "steps": steps,
                   "batch": loader.batch_size,
                   "seq_len": int(train["tokens"].shape[1]),
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "variants": variants,
        # contract consumed by benchmarks/check_regression.py: temp-memory
        # reductions are buffer-assigner facts (deterministic per config),
        # so they are marked stable and tracked tightly vs the baseline;
        # steps/sec ratios stay informational on shared CI runners
        "tracked": {
            "accum4_peak_mem_reduction": {
                "value": variants["accum4"]["peak_mem_reduction_vs_f32"],
                "floor": 0.3, "stable": True},
            "bf16_accum4_peak_mem_reduction": {
                "value": variants["bf16_accum4"]
                ["peak_mem_reduction_vs_f32"],
                "floor": 0.3, "stable": True},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    got = variants["accum4"]["peak_mem_reduction_vs_f32"]
    if args.min_mem_reduction and got < args.min_mem_reduction:
        raise SystemExit(
            f"accum4 peak-memory reduction {got:.0%} below the "
            f"{args.min_mem_reduction:.0%} bar")


if __name__ == "__main__":
    main()
