"""Table 3 analog (ImageNet): the paper accelerates a transformer-scale
pipeline with TWO phase-2 workers and no extra tuning beyond doubling LR with
batch size. We mirror that on the LM task with a transformer arch: large
batch = 2x small batch, LR doubled, phase 2 = 2 workers on the original
schedule."""
from __future__ import annotations

import json

from benchmarks.common import lm_task, mean_std, run_sgd, run_swap

SMALL = dict(batch_size=64, steps=240, peak_lr=0.5)
LARGE = dict(batch_size=128, steps=120, peak_lr=1.0)
SWAP_HP = dict(workers=2, b1=128, b2=64, steps1=120, steps2=60,
               lr1=1.0, lr2=0.25, stop_acc=0.68)


def run(seeds=(0, 1, 2), verbose=True):
    rows = {"SGD (small-batch)": [], "SGD (large-batch)": [],
            "SWAP (before averaging)": [], "SWAP (after averaging)": []}
    times = {k: [] for k in rows}
    for seed in seeds:
        adapter, train, test_loader = lm_task(seed=seed)
        small = run_sgd(adapter, train, test_loader, seed=seed, **SMALL)
        large = run_sgd(adapter, train, test_loader, seed=seed, **LARGE)
        swap = run_swap(adapter, train, test_loader, seed=seed, **SWAP_HP)
        rows["SGD (small-batch)"].append(small["test_acc"])
        rows["SGD (large-batch)"].append(large["test_acc"])
        rows["SWAP (before averaging)"].append(swap["before_avg_test_acc"])
        rows["SWAP (after averaging)"].append(swap["after_avg_test_acc"])
        times["SGD (small-batch)"].append(small["time"])
        times["SGD (large-batch)"].append(large["time"])
        swap_t = swap["phase1_time"] + swap["phase2_time"]
        times["SWAP (before averaging)"].append(swap_t)
        times["SWAP (after averaging)"].append(swap_t + swap["phase3_time"])
    out = {}
    if verbose:
        print("\n== Table 3 analog (ImageNet protocol / LM task, 2 workers) ==")
        print(f"{'row':28s} {'test acc':>20s} {'time (s)':>20s}")
    for k in rows:
        out[k] = {"acc": rows[k], "time": times[k]}
        if verbose:
            print(f"{k:28s} {mean_std(rows[k]):>20s} {mean_std(times[k]):>20s}")
    return out


def main():
    out = run()
    with open("results/table3.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
