"""Live-publish overhead: decode throughput with mid-serving weight swaps.

Runs the SAME finite-budget request workload (slots keep turning over, so
new admissions pick up fresh generations and old ones drain — the mixed
dual-generation window actually opens) through a compiled engine twice:

  * **no-swap** — the pre-publishing steady state; this floor must match
    BENCH_serve's regime (publishing support may cost nothing when idle).
  * **with swaps** — ``publish()`` of a new weight generation every N
    decode calls: host->device staging of the inactive buffer, per-slot
    generation pinning, and the dual-generation decode program while
    generations overlap in flight.

Tracked contract (checked-in floor enforced by check_regression.py):
``swap_overhead_ratio`` = with-swaps tokens/s over no-swap tokens/s — the
hot-swap machinery may tax throughput only so far even when publishing
every 4th call (far denser than the one-publish-per-epoch reality); and
``single_transfer_with_swaps`` — swaps must add ZERO device->host syncs
(``decode_transfers == decode_calls`` across the whole swap-heavy phase).

  PYTHONPATH=src python benchmarks/bench_publish.py --smoke \
      [--out BENCH_publish.json]
"""
from __future__ import annotations

import argparse
import json
import time

import bench_serve
import jax

from repro.models.model import Model
from repro.serve.compiled import CompiledServingEngine
from repro.serve.engine import Request


def _workload(cfg, n_requests, prompt_len, budget):
    # staggered budgets desynchronize slot completions: without them all
    # slots admit/finish in lockstep and generations never overlap
    prompts = bench_serve._prompts(cfg, n_requests, prompt_len, seed=13)
    return [Request(rid=i, prompt=p, max_new_tokens=budget + 2 * (i % 3))
            for i, p in enumerate(prompts)]


def _run(engine, cfg, *, slots, prompt_len, budget, warm_calls, timed_calls,
         publish_every=0, pub_params=()):
    """Serve a continuously refilled pool of finite-budget requests for
    ``timed_calls`` decode calls; returns tokens/s over the timed phase.
    With ``publish_every``, a new weight generation is published every
    N-th call (alternating over ``pub_params``)."""
    total_calls = warm_calls + timed_calls
    # each request lasts ceil(budget/block) calls per slot; queue enough
    # that admission pressure never lets a slot idle
    n_req = slots * (total_calls + 4)
    pool = _workload(cfg, n_req, prompt_len, budget)
    for r in pool:
        engine.submit(r)
    engine.warmup(dual=bool(publish_every))

    published = 0

    def maybe_publish(call):
        nonlocal published
        if publish_every and call % publish_every == 0:
            engine.publish(pub_params[published % len(pub_params)])
            published += 1

    for c in range(warm_calls):                   # includes mixed windows
        maybe_publish(c)
        engine.step()
    done_before = sum(len(r.generated) for r in pool)
    stats0 = dict(engine.stats)
    t0 = time.perf_counter()
    for c in range(timed_calls):
        maybe_publish(warm_calls + c)
        engine.step()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in pool) - done_before
    assert engine.active == slots, "the request pool ran dry mid-bench"
    delta = {k: engine.stats[k] - stats0[k] for k in engine.stats}
    return tokens / dt, delta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block", type=int, default=4,
                    help="decode_block K (short blocks -> frequent "
                         "admissions -> generations actually mix)")
    ap.add_argument("--publish-every", type=int, default=4,
                    help="publish a new generation every N decode calls")
    ap.add_argument("--calls", type=int, default=0,
                    help="timed decode calls (default: 48 smoke / 96 full)")
    ap.add_argument("--out", default="BENCH_publish.json")
    args = ap.parse_args()

    timed = args.calls or (48 if args.smoke else 96)
    warm = 12
    prompt_len = 16
    budget = 2 * args.block                       # ~2-3 calls per request
    max_seq = prompt_len + budget + 4 + args.block + 8
    cfg = bench_serve.bench_model(args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # alternating perturbed generations, device-resident up front (as the
    # in-process publisher's averages are)
    pub = tuple(
        jax.block_until_ready(jax.tree_util.tree_map(
            lambda x, s=s: x * (1.0 + 0.01 * s), params))
        for s in (1, 2))

    def make():
        return CompiledServingEngine(model, params, max_batch=args.slots,
                                     max_seq=max_seq,
                                     decode_block=args.block,
                                     prefill_buckets=[prompt_len])

    common = dict(slots=args.slots, prompt_len=prompt_len, budget=budget,
                  warm_calls=warm, timed_calls=timed)
    tok_plain, d_plain = _run(make(), cfg, **common)
    tok_swap, d_swap = _run(make(), cfg, **common,
                            publish_every=args.publish_every,
                            pub_params=pub)

    ratio = tok_swap / tok_plain
    single = 1.0 if (d_swap["decode_transfers"] == d_swap["decode_calls"]
                     and d_plain["decode_transfers"]
                     == d_plain["decode_calls"]) else 0.0
    out = {
        "config": {"arch": cfg.name, "params": cfg.param_count(),
                   "smoke": args.smoke, "slots": args.slots,
                   "decode_block": args.block, "prompt_len": prompt_len,
                   "budget": budget, "timed_calls": timed,
                   "publish_every": args.publish_every,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "decode": {"no_swap_tokens_per_s": round(tok_plain, 2),
                   "with_swaps_tokens_per_s": round(tok_swap, 2),
                   "overhead_ratio": round(ratio, 3)},
        "publish": {"publishes": d_swap["publishes"],
                    "swaps_applied": d_swap["publish_swaps"],
                    "superseded": d_swap["publish_superseded"],
                    "dual_decode_calls": d_swap["dual_decode_calls"],
                    "decode_calls": d_swap["decode_calls"],
                    "host_transfers": d_swap["decode_transfers"]},
        # consumed by benchmarks/check_regression.py (CI bench job).
        # swap_overhead_ratio is runner-noise-robust (both phases share
        # the per-step model math on the same process); the floor says a
        # publish every 4th call may cost at most half the throughput.
        # single_transfer_with_swaps is the no-new-host-syncs invariant.
        "tracked": {
            "swap_overhead_ratio": {"value": round(ratio, 3), "floor": 0.5},
            "single_transfer_with_swaps": {"value": single, "floor": 1.0},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    if d_swap["dual_decode_calls"] == 0:
        raise SystemExit("workload never mixed generations — the bench "
                         "did not exercise the dual-generation program")


if __name__ == "__main__":
    main()
