"""Roofline models + report.

Two layers:
  * the per-(arch x shape x mesh) table rendered from the dry-run JSON
    (see EXPERIMENTS.md §Roofline) — no computation, numbers come from
    compiled artifacts;
  * per-kernel analytical bytes/FLOPs models (``kernel_model``) used by
    ``bench_kernels.py`` to score each measured design point against the
    backend's roofline bound (``kernel_bound_s``) — the sanity check that
    makes sweep output interpretable (a "winner" at 1% of roofline is a
    scheduling artifact, not a good tile).
"""
from __future__ import annotations

import json
import os

HW = "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"

# peak (flops/s, bytes/s) per backend for the kernel roofline bound.
# tpu: v5e bf16 MXU + HBM (the HW line above); gpu: A100-40GB-class f32
# tensor-core-free peak + HBM2e; cpu: one AVX-512 server core-ish — only
# used so smoke-mode fractions are finite, never as a promise.
KERNEL_HW = {
    "tpu": {"flops": 197e12, "bytes": 819e9},
    "gpu": {"flops": 19.5e12, "bytes": 1.55e12},
    "cpu": {"flops": 5e10, "bytes": 2e10},
}

_DTYPE_BYTES = 4   # kernels accumulate f32; benches feed f32 operands


def kernel_model(kernel: str, **s) -> dict:
    """Analytical {flops, bytes} for one forward call of a kernel.

    Shape kwargs per kernel:
      flash_attention: b, sq, skv, h, kvh, d
      ssd:             b, s, h, p, n, chunk   (intra-chunk kernel only)
      swa_avg:         numel
    """
    e = _DTYPE_BYTES
    if kernel == "flash_attention":
        b, sq, skv, h, d = s["b"], s["sq"], s["skv"], s["h"], s["d"]
        kvh = s.get("kvh", h)
        # QK^T and PV, 2*M*N*K each; softmax/elementwise folded into bytes
        flops = 2 * (2 * b * sq * skv * h * d)
        bytes_ = e * (2 * b * sq * h * d          # q read, out write
                      + 2 * b * skv * kvh * d     # k, v read
                      + b * sq * h)               # lse write
        return {"flops": flops, "bytes": bytes_}
    if kernel == "ssd":
        b, sl, h, p, n = s["b"], s["s"], s["h"], s["p"], s["n"]
        L = s["chunk"]
        nc = sl // L
        # per (b*h, chunk) program: scores (L,N)x(N,L), y (L,L)x(L,P),
        # state (P,L)x(L,N)
        flops = b * h * nc * 2 * (L * L * n + L * L * p + L * p * n)
        bytes_ = e * b * h * (sl * p * 2          # x read, y write
                              + sl * 2            # dt read, cum write
                              + sl * n * 2        # B, C read
                              + nc * p * n)       # states write
        return {"flops": flops, "bytes": bytes_}
    if kernel == "swa_avg":
        numel = s["numel"]
        return {"flops": 3 * numel,               # sub, div, add
                "bytes": e * 3 * numel}           # avg + w read, out write
    raise ValueError(f"unknown kernel {kernel!r}")


def kernel_bound_s(kernel: str, backend: str, **shape) -> float:
    """Roofline lower bound (seconds) for one call on ``backend``: the
    slower of the compute and memory terms. Measured time below this bound
    means the model (or the timer) is wrong — bench_kernels warns."""
    hw = KERNEL_HW[backend]
    m = kernel_model(kernel, **shape)
    return max(m["flops"] / hw["flops"], m["bytes"] / hw["bytes"])


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:7.2f}s"
    return f"{x*1e3:6.1f}ms"


def run(path="results/dryrun.json", verbose=True, mesh="single"):
    if not os.path.exists(path):
        print(f"(roofline: {path} missing — run repro.launch.dryrun first)")
        return {}
    with open(path) as f:
        data = json.load(f)
    rows = []
    for key, r in sorted(data.items()):
        if r.get("phase2") or r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "SKIP", None, None, None,
                         None, None))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERR", None, None, None,
                         None, None))
            continue
        rows.append((r["arch"], r["shape"], r["bottleneck"], r["compute_s"],
                     r["memory_s"], r["collective_s"],
                     r["useful_compute_ratio"],
                     r.get("memory_analysis", {}).get("temp_bytes")))
    if verbose:
        print(f"\n== Roofline ({mesh} pod; {HW}) ==")
        print(f"{'arch':24s} {'shape':12s} {'bottleneck':10s} "
              f"{'compute':>9s} {'memory':>9s} {'collect.':>9s} "
              f"{'MF/HLO':>7s} {'temp GB/dev':>11s}")
        for a, s, bn, c, m, co, ur, tb in rows:
            ur_s = f"{ur:.3f}" if ur else "-"
            tb_s = f"{tb/2**30:.2f}" if tb else "-"
            print(f"{a:24s} {s:12s} {bn:10s} {fmt_s(c):>9s} {fmt_s(m):>9s} "
                  f"{fmt_s(co):>9s} {ur_s:>7s} {tb_s:>11s}")
    return rows


def main():
    run(mesh="single")
    run(mesh="multi")


if __name__ == "__main__":
    main()
