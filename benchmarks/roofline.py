"""Roofline report: renders the per-(arch x shape x mesh) table from the
dry-run JSON (see EXPERIMENTS.md §Roofline). No computation here — the
numbers come from compiled artifacts."""
from __future__ import annotations

import json
import os

HW = "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:7.2f}s"
    return f"{x*1e3:6.1f}ms"


def run(path="results/dryrun.json", verbose=True, mesh="single"):
    if not os.path.exists(path):
        print(f"(roofline: {path} missing — run repro.launch.dryrun first)")
        return {}
    with open(path) as f:
        data = json.load(f)
    rows = []
    for key, r in sorted(data.items()):
        if r.get("phase2") or r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "SKIP", None, None, None,
                         None, None))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERR", None, None, None,
                         None, None))
            continue
        rows.append((r["arch"], r["shape"], r["bottleneck"], r["compute_s"],
                     r["memory_s"], r["collective_s"],
                     r["useful_compute_ratio"],
                     r.get("memory_analysis", {}).get("temp_bytes")))
    if verbose:
        print(f"\n== Roofline ({mesh} pod; {HW}) ==")
        print(f"{'arch':24s} {'shape':12s} {'bottleneck':10s} "
              f"{'compute':>9s} {'memory':>9s} {'collect.':>9s} "
              f"{'MF/HLO':>7s} {'temp GB/dev':>11s}")
        for a, s, bn, c, m, co, ur, tb in rows:
            ur_s = f"{ur:.3f}" if ur else "-"
            tb_s = f"{tb/2**30:.2f}" if tb else "-"
            print(f"{a:24s} {s:12s} {bn:10s} {fmt_s(c):>9s} {fmt_s(m):>9s} "
                  f"{fmt_s(co):>9s} {ur_s:>7s} {tb_s:>11s}")
    return rows


def main():
    run(mesh="single")
    run(mesh="multi")


if __name__ == "__main__":
    main()
