"""Serving-engine throughput: per-step python engine vs compiled engine.

Measures, on the same model / slot pool / workload:

  * **decode tokens/s** — a pure-decode phase with every slot busy and no
    admissions: the python ``ServingEngine`` dispatches one jitted step
    and blocks on B per-slot ``int()`` syncs per token; the
    ``CompiledServingEngine`` runs K fused steps per host call with ONE
    bulk (B, K) transfer.
  * **admission latency** — ``submit()`` of a max_new_tokens=1 request
    into a free slot: bucket-padded prefill + jitted bulk cache scatter
    (compiled) vs exact-length prefill + host-side leaf-by-leaf pytree
    rebuild (python).
  * **transfers per decode call** — the zero-per-token-host-round-trip
    claim, verified from the compiled engine's instrumentation:
    ``decode_transfers == decode_calls`` over the whole timed phase.
  * **open-loop latency** — Poisson arrivals against the paged int8
    engine at ~70% of calibrated service capacity: per-request p50/p99
    latency (arrival -> done, queueing included), the way a production
    server is actually loaded. Tracked as inverse seconds so the
    regression floors stay higher-is-better.
  * **concurrency at fixed cache bytes** — the tentpole claim: pools the
    dense-f32 engine's exact cache byte budget into a paged int8 engine
    and measures peak concurrently-decoding requests on a backlog of
    short requests. Paging (pages for the prompt, not a max_seq slab) and
    int8 (~4x tokens/byte) compound; the floor is the acceptance bar (2x).

The classic engine-vs-engine sections pin ``kv_layout="dense"`` so their
baselines keep measuring host-dispatch overhead, not layout effects.
Compile time is excluded (warmup admissions + decode calls on both
sides). Emits ``BENCH_serve.json``; the acceptance bar is >= 2x compiled
decode tokens/s on the CPU smoke config, enforced via the ``tracked``
floors by benchmarks/check_regression.py in the CI bench job.

  PYTHONPATH=src python benchmarks/bench_serve.py --smoke \
      [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.serve.compiled import CompiledServingEngine
from repro.serve.engine import Request, ServingEngine


def bench_model(smoke: bool) -> ModelConfig:
    """Small dense LM (same rationale as bench_train_loop.bench_model):
    the engines run identical per-step math and differ in host dispatch /
    sync overhead, so the benchmark sizes the step to be cheap — the
    regime the engine targets (on an accelerator the decode step IS cheap
    relative to the host loop; a big model on this CPU host would just
    hide the loop behind arithmetic)."""
    scale = 1 if smoke else 2
    return ModelConfig(
        name="bench-serve-lm", family="dense", n_layers=2,
        d_model=32 * scale, n_heads=4, n_kv_heads=2, head_dim=8 * scale,
        d_ff=64 * scale, vocab_size=256, attention="gqa", dtype="float32",
        remat=False, scan_layers=False)


def _prompts(cfg, n, length, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (length,), 0,
                               cfg.vocab_size, dtype=jnp.int32)
            for i in range(n)]


def _bench_admission(engine, cfg, prompt_len, n_admits):
    """Mean submit() latency for a request that finishes at admission
    (max_new_tokens=1 -> the slot frees immediately; every submit is a
    fresh prefill + scatter). First submit compiles and is discarded."""
    prompts = _prompts(cfg, n_admits + 1, prompt_len, seed=7)
    engine.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=1))
    times = []
    for i in range(n_admits):
        t0 = time.perf_counter()
        engine.submit(Request(rid=i, prompt=prompts[i + 1],
                              max_new_tokens=1))
        times.append(time.perf_counter() - t0)
    return sum(times) / len(times)


def _bench_decode(engine, cfg, *, slots, prompt_len, warmup_steps,
                  timed_steps, block):
    """Pure-decode tokens/s: fill every slot with a budget that outlives
    the run, warm the decode program up, then time. Returns tok/s."""
    budget = warmup_steps + timed_steps + block + 4
    for i, p in enumerate(_prompts(cfg, slots, prompt_len, seed=11)):
        engine.submit(Request(rid=100 + i, prompt=p, max_new_tokens=budget))
    assert engine.active == slots
    is_compiled = isinstance(engine, CompiledServingEngine)
    per_call = block if is_compiled else 1
    for _ in range(max(1, warmup_steps // per_call)):
        engine.step()
    calls = timed_steps // per_call
    t0 = time.perf_counter()
    for _ in range(calls):
        engine.step()
    dt = time.perf_counter() - t0
    assert engine.active == slots, "a slot finished inside the timed phase"
    return slots * calls * per_call / dt


def _drain(engine, max_steps=100_000):
    steps = 0
    while (engine.active or engine.waiting) and steps < max_steps:
        engine.step()
        steps += 1
    assert not (engine.active or engine.waiting), "engine failed to drain"


def _bench_open_loop(engine, cfg, *, slots, block, prompt_len, budget,
                     n_requests, util=0.7, seed=23):
    """Poisson arrivals at ``util`` x calibrated service capacity: submit
    on an exponential-gap wall-clock schedule, record arrival->done latency
    (queueing included). Returns (p50_s, p99_s, arrival_rate_rps)."""
    # warm every program (prefill bucket, admit, decode), then calibrate
    # step time with all slots busy
    for i, p in enumerate(_prompts(cfg, slots, prompt_len, seed=seed)):
        engine.submit(Request(rid=-100 - i, prompt=p,
                              max_new_tokens=4 * block))
    for _ in range(2):
        engine.step()
    t0 = time.perf_counter()
    engine.step()
    step_time = time.perf_counter() - t0
    _drain(engine)
    # service rate ~ slots*K tokens per step; a request costs ~budget tokens
    rate = util * slots * block / (step_time * budget)

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=budget)
            for i, p in enumerate(_prompts(cfg, n_requests, prompt_len,
                                           seed=seed + 1))]
    done_at = {}
    t0 = time.perf_counter()
    n_in = 0
    while len(done_at) < n_requests:
        now = time.perf_counter() - t0
        while n_in < n_requests and arrivals[n_in] <= now:
            engine.submit(reqs[n_in])
            n_in += 1
        if engine.active or engine.waiting:
            engine.step()
        elif n_in < n_requests:
            time.sleep(min(1e-3, max(0.0, arrivals[n_in] - now)))
        now = time.perf_counter() - t0
        for r in reqs[:n_in]:
            if r.done and r.rid not in done_at:
                done_at[r.rid] = now
    lat = np.array([done_at[i] - arrivals[i] for i in range(n_requests)])
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99)), rate


def _bench_concurrency(model, params, *, max_seq, block, dense_slots,
                       paged_slots, prompt_len, budget, n_requests):
    """Peak concurrently-decoding requests when the paged int8 engine is
    given EXACTLY the dense f32 engine's cache byte budget. Two probe
    engines solve for bytes-per-page (construction is cheap: jits are
    lazy and never traced here)."""
    mk = lambda **kw: CompiledServingEngine(
        model, params, max_seq=max_seq, decode_block=block, **kw)
    budget_bytes = mk(max_batch=dense_slots,
                      kv_layout="dense").cache_bytes()
    paged = lambda n: mk(max_batch=paged_slots, kv_layout="paged",
                         kv_cache_dtype="int8", n_pages=n)
    b2, b3 = paged(2).cache_bytes(), paged(3).cache_bytes()
    per_page = b3 - b2
    n_pages = 2 + (budget_bytes - b2) // per_page
    engine = paged(int(n_pages))
    assert engine.cache_bytes() <= budget_bytes

    cfg = model.cfg
    for i, p in enumerate(_prompts(cfg, n_requests, prompt_len, seed=31)):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=budget))
    peak = engine.active
    steps = 0
    while (engine.active or engine.waiting) and steps < 100_000:
        engine.step()
        peak = max(peak, engine.active)
        steps += 1
    assert not (engine.active or engine.waiting)
    return {"dense_slots": dense_slots, "dense_bytes": int(budget_bytes),
            "paged_bytes": int(engine.cache_bytes()),
            "n_pages": int(engine.n_pages),
            "page_size": engine.page_size,
            "peak_concurrent": int(peak),
            "admit_page_waits": engine.stats["admit_page_waits"],
            "ratio": round(peak / dense_slots, 2)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block", type=int, default=8,
                    help="decode_block K for the compiled engine")
    ap.add_argument("--steps", type=int, default=0,
                    help="timed decode steps (default: 48 smoke / 96 full)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if compiled decode speedup falls "
                         "below this (0 = report only)")
    args = ap.parse_args()

    timed = args.steps or (48 if args.smoke else 96)
    warmup = 2 * args.block
    prompt_len = 16
    max_seq = prompt_len + warmup + timed + 2 * args.block + 8
    cfg = bench_model(args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(kind):
        if kind == "compiled":
            # dense pinned: this section's baseline measures host-dispatch
            # overhead vs the python engine, not cache-layout effects
            return CompiledServingEngine(
                model, params, max_batch=args.slots, max_seq=max_seq,
                decode_block=args.block, kv_layout="dense")
        return ServingEngine(model, params, max_batch=args.slots,
                             max_seq=max_seq)

    n_admits = 4 if args.smoke else 8
    admit_py = _bench_admission(make("loop"), cfg, prompt_len, n_admits)
    eng_c = make("compiled")
    admit_c = _bench_admission(eng_c, cfg, prompt_len, n_admits)

    # decode on fresh engines (per-instance jits; admission bench already
    # compiled eng_c's prefill+scatter, so reuse it and keep the python
    # engine symmetric)
    tok_py = _bench_decode(make("loop"), cfg, slots=args.slots,
                           prompt_len=prompt_len, warmup_steps=warmup,
                           timed_steps=timed, block=args.block)
    c0 = dict(eng_c.stats)
    tok_c = _bench_decode(eng_c, cfg, slots=args.slots,
                          prompt_len=prompt_len, warmup_steps=warmup,
                          timed_steps=timed, block=args.block)
    calls = eng_c.stats["decode_calls"] - c0["decode_calls"]
    transfers = eng_c.stats["decode_transfers"] - c0["decode_transfers"]
    # the fused loop's contract: ONE device->host transfer per K-token
    # scan call — i.e. zero per-token round-trips
    single_transfer = 1.0 if transfers == calls else 0.0

    # open-loop Poisson load on the production layout (paged int8)
    eng_p = CompiledServingEngine(
        model, params, max_batch=args.slots, max_seq=max_seq,
        decode_block=args.block, kv_layout="paged", kv_cache_dtype="int8")
    n_open = 24 if args.smoke else 48
    p50, p99, rate = _bench_open_loop(
        eng_p, cfg, slots=args.slots, block=args.block,
        prompt_len=prompt_len, budget=4 * args.block, n_requests=n_open)
    open_transfers_ok = (eng_p.stats["decode_transfers"]
                         == eng_p.stats["decode_calls"])

    conc = _bench_concurrency(
        model, params, max_seq=max_seq, block=args.block,
        dense_slots=args.slots, paged_slots=32, prompt_len=prompt_len,
        budget=args.block, n_requests=48 if args.smoke else 96)

    speedup = tok_c / tok_py
    out = {
        "config": {"arch": cfg.name, "params": cfg.param_count(),
                   "smoke": args.smoke, "slots": args.slots,
                   "decode_block": args.block, "prompt_len": prompt_len,
                   "timed_steps": timed, "max_seq": max_seq,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "decode": {"python_tokens_per_s": round(tok_py, 2),
                   "compiled_tokens_per_s": round(tok_c, 2),
                   "speedup": round(speedup, 2)},
        "admission": {"python_ms": round(admit_py * 1e3, 2),
                      "compiled_ms": round(admit_c * 1e3, 2),
                      "speedup": round(admit_py / admit_c, 2)},
        "transfers": {"decode_calls": calls,
                      "host_transfers": transfers},
        "open_loop": {"layout": "paged-int8",
                      "n_requests": n_open,
                      "arrival_rate_rps": round(rate, 2),
                      "p50_ms": round(p50 * 1e3, 2),
                      "p99_ms": round(p99 * 1e3, 2),
                      "single_transfer_per_decode_call": open_transfers_ok},
        "concurrency_at_fixed_bytes": conc,
        # contract consumed by benchmarks/check_regression.py (CI bench
        # job). decode_speedup's floor IS the acceptance bar (2x); the
        # ratio is runner-noise-robust because both engines share the
        # per-step model math. single_transfer_per_decode_call is the
        # zero-per-token-round-trip invariant (1.0 or the job fails).
        "tracked": {
            "decode_speedup": {"value": round(speedup, 2), "floor": 2.0},
            "admission_speedup": {"value": round(admit_py / admit_c, 2),
                                  "floor": 0.5},
            "single_transfer_per_decode_call": {"value": single_transfer,
                                                "floor": 1.0},
            # latencies tracked as inverse seconds (higher is better);
            # floors are generous — they catch order-of-magnitude
            # regressions, not runner jitter (p50 <= 10s, p99 <= 50s)
            "open_loop_p50_inv": {"value": round(1.0 / p50, 3),
                                  "floor": 0.1},
            "open_loop_p99_inv": {"value": round(1.0 / p99, 3),
                                  "floor": 0.02},
            # the acceptance bar: >= 2x max concurrent requests at the
            # dense engine's exact cache byte budget (paged + int8
            # compound; the smoke config lands ~8x)
            "concurrency_at_fixed_bytes": {"value": conc["ratio"],
                                           "floor": 2.0},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(f"compiled decode speedup {speedup:.2f}x below "
                         f"the {args.min_speedup}x bar")


if __name__ == "__main__":
    main()
