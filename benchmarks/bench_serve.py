"""Serving-engine throughput: per-step python engine vs compiled engine.

Measures, on the same model / slot pool / workload:

  * **decode tokens/s** — a pure-decode phase with every slot busy and no
    admissions: the python ``ServingEngine`` dispatches one jitted step
    and blocks on B per-slot ``int()`` syncs per token; the
    ``CompiledServingEngine`` runs K fused steps per host call with ONE
    bulk (B, K) transfer.
  * **admission latency** — ``submit()`` of a max_new_tokens=1 request
    into a free slot: bucket-padded prefill + jitted bulk cache scatter
    (compiled) vs exact-length prefill + host-side leaf-by-leaf pytree
    rebuild (python).
  * **transfers per decode call** — the zero-per-token-host-round-trip
    claim, verified from the compiled engine's instrumentation:
    ``decode_transfers == decode_calls`` over the whole timed phase.

Compile time is excluded (warmup admissions + decode calls on both
sides). Emits ``BENCH_serve.json``; the acceptance bar is >= 2x compiled
decode tokens/s on the CPU smoke config, enforced via the ``tracked``
floors by benchmarks/check_regression.py in the CI bench job.

  PYTHONPATH=src python benchmarks/bench_serve.py --smoke \
      [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.serve.compiled import CompiledServingEngine
from repro.serve.engine import Request, ServingEngine


def bench_model(smoke: bool) -> ModelConfig:
    """Small dense LM (same rationale as bench_train_loop.bench_model):
    the engines run identical per-step math and differ in host dispatch /
    sync overhead, so the benchmark sizes the step to be cheap — the
    regime the engine targets (on an accelerator the decode step IS cheap
    relative to the host loop; a big model on this CPU host would just
    hide the loop behind arithmetic)."""
    scale = 1 if smoke else 2
    return ModelConfig(
        name="bench-serve-lm", family="dense", n_layers=2,
        d_model=32 * scale, n_heads=4, n_kv_heads=2, head_dim=8 * scale,
        d_ff=64 * scale, vocab_size=256, attention="gqa", dtype="float32",
        remat=False, scan_layers=False)


def _prompts(cfg, n, length, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (length,), 0,
                               cfg.vocab_size, dtype=jnp.int32)
            for i in range(n)]


def _bench_admission(engine, cfg, prompt_len, n_admits):
    """Mean submit() latency for a request that finishes at admission
    (max_new_tokens=1 -> the slot frees immediately; every submit is a
    fresh prefill + scatter). First submit compiles and is discarded."""
    prompts = _prompts(cfg, n_admits + 1, prompt_len, seed=7)
    engine.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=1))
    times = []
    for i in range(n_admits):
        t0 = time.perf_counter()
        engine.submit(Request(rid=i, prompt=prompts[i + 1],
                              max_new_tokens=1))
        times.append(time.perf_counter() - t0)
    return sum(times) / len(times)


def _bench_decode(engine, cfg, *, slots, prompt_len, warmup_steps,
                  timed_steps, block):
    """Pure-decode tokens/s: fill every slot with a budget that outlives
    the run, warm the decode program up, then time. Returns tok/s."""
    budget = warmup_steps + timed_steps + block + 4
    for i, p in enumerate(_prompts(cfg, slots, prompt_len, seed=11)):
        engine.submit(Request(rid=100 + i, prompt=p, max_new_tokens=budget))
    assert engine.active == slots
    is_compiled = isinstance(engine, CompiledServingEngine)
    per_call = block if is_compiled else 1
    for _ in range(max(1, warmup_steps // per_call)):
        engine.step()
    calls = timed_steps // per_call
    t0 = time.perf_counter()
    for _ in range(calls):
        engine.step()
    dt = time.perf_counter() - t0
    assert engine.active == slots, "a slot finished inside the timed phase"
    return slots * calls * per_call / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same config the acceptance bar uses)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block", type=int, default=8,
                    help="decode_block K for the compiled engine")
    ap.add_argument("--steps", type=int, default=0,
                    help="timed decode steps (default: 48 smoke / 96 full)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if compiled decode speedup falls "
                         "below this (0 = report only)")
    args = ap.parse_args()

    timed = args.steps or (48 if args.smoke else 96)
    warmup = 2 * args.block
    prompt_len = 16
    max_seq = prompt_len + warmup + timed + 2 * args.block + 8
    cfg = bench_model(args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(kind):
        if kind == "compiled":
            return CompiledServingEngine(
                model, params, max_batch=args.slots, max_seq=max_seq,
                decode_block=args.block)
        return ServingEngine(model, params, max_batch=args.slots,
                             max_seq=max_seq)

    n_admits = 4 if args.smoke else 8
    admit_py = _bench_admission(make("loop"), cfg, prompt_len, n_admits)
    eng_c = make("compiled")
    admit_c = _bench_admission(eng_c, cfg, prompt_len, n_admits)

    # decode on fresh engines (per-instance jits; admission bench already
    # compiled eng_c's prefill+scatter, so reuse it and keep the python
    # engine symmetric)
    tok_py = _bench_decode(make("loop"), cfg, slots=args.slots,
                           prompt_len=prompt_len, warmup_steps=warmup,
                           timed_steps=timed, block=args.block)
    c0 = dict(eng_c.stats)
    tok_c = _bench_decode(eng_c, cfg, slots=args.slots,
                          prompt_len=prompt_len, warmup_steps=warmup,
                          timed_steps=timed, block=args.block)
    calls = eng_c.stats["decode_calls"] - c0["decode_calls"]
    transfers = eng_c.stats["decode_transfers"] - c0["decode_transfers"]
    # the fused loop's contract: ONE device->host transfer per K-token
    # scan call — i.e. zero per-token round-trips
    single_transfer = 1.0 if transfers == calls else 0.0

    speedup = tok_c / tok_py
    out = {
        "config": {"arch": cfg.name, "params": cfg.param_count(),
                   "smoke": args.smoke, "slots": args.slots,
                   "decode_block": args.block, "prompt_len": prompt_len,
                   "timed_steps": timed, "max_seq": max_seq,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "decode": {"python_tokens_per_s": round(tok_py, 2),
                   "compiled_tokens_per_s": round(tok_c, 2),
                   "speedup": round(speedup, 2)},
        "admission": {"python_ms": round(admit_py * 1e3, 2),
                      "compiled_ms": round(admit_c * 1e3, 2),
                      "speedup": round(admit_py / admit_c, 2)},
        "transfers": {"decode_calls": calls,
                      "host_transfers": transfers},
        # contract consumed by benchmarks/check_regression.py (CI bench
        # job). decode_speedup's floor IS the acceptance bar (2x); the
        # ratio is runner-noise-robust because both engines share the
        # per-step model math. single_transfer_per_decode_call is the
        # zero-per-token-round-trip invariant (1.0 or the job fails).
        "tracked": {
            "decode_speedup": {"value": round(speedup, 2), "floor": 2.0},
            "admission_speedup": {"value": round(admit_py / admit_c, 2),
                                  "floor": 0.5},
            "single_transfer_per_decode_call": {"value": single_transfer,
                                                "floor": 1.0},
        },
    }
    print(json.dumps(out, indent=1))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(f"compiled decode speedup {speedup:.2f}x below "
                         f"the {args.min_speedup}x bar")


if __name__ == "__main__":
    main()
